//! Block-sparse attention selection (FlashPrefill/UniPrefill-style):
//! keys are pooled into fixed-size blocks, each query-block×key-block
//! pair is scored with a cheap pooled-QK estimate, and per query block
//! per head only the top-scoring key blocks are attended — always
//! including a mandatory *sink + local* streaming band so early and
//! recent context survive regardless of scores.
//!
//! Everything here is **pure selection**: the functions decide *which*
//! key blocks a query block reads, never the attention values
//! themselves. The CPU kernel (`runtime/cpu.rs`) then iterates the
//! selected blocks in ascending order with the dense kernel's exact
//! per-element accumulation order, so a selection covering every causal
//! block reproduces the dense output **bit for bit** — the oracle
//! contract `tests/backend_conformance.rs` pins. Selection runs
//! sequentially on the dispatching thread before any row-parallel work,
//! so it is invariant under thread count by construction.

/// Mandatory sink band: the first `SINK_BLOCKS` key blocks are always
/// attended (attention-sink positions, StreamingLLM-style).
pub const SINK_BLOCKS: usize = 1;

/// Mandatory local band: the last `LOCAL_BLOCKS` causal key blocks
/// (the query's own block and its predecessor) are always attended.
pub const LOCAL_BLOCKS: usize = 2;

/// Select the key blocks one (query block, head) pair attends.
///
/// `scores[b]` is the pooled-QK estimate for causal key block `b`
/// (`b ∈ 0..=qb`, where `qb` is the query block's absolute index).
/// `drop ∈ [0, 1]` is the fraction of *optional* candidates discarded:
/// the sink + local band is always kept, and of the remaining causal
/// blocks the top `ceil((1 − drop) · n_optional)` by score survive
/// (ties broken toward the lower block index). `drop == 0.0` therefore
/// selects every causal block and `drop == 1.0` degenerates to the
/// sink + local band alone. Returns ascending, duplicate-free indices.
pub fn select_blocks(scores: &[f32], qb: usize, drop: f64) -> Vec<u32> {
    assert!(scores.len() > qb, "need a score for every causal block");
    assert!((0.0..=1.0).contains(&drop), "drop must be in [0, 1]");
    let mandatory = |b: usize| -> bool {
        b < SINK_BLOCKS || b + LOCAL_BLOCKS > qb
    };
    let optional: Vec<usize> =
        (0..=qb).filter(|&b| !mandatory(b)).collect();
    let keep = ((1.0 - drop) * optional.len() as f64)
        .ceil()
        .min(optional.len() as f64) as usize;
    let mut ranked = optional;
    // score descending, then block index ascending — a total order, so
    // the pick is deterministic even under tied pooled scores
    ranked.sort_by(|&a, &b| {
        scores[b]
            .total_cmp(&scores[a])
            .then_with(|| a.cmp(&b))
    });
    ranked.truncate(keep);
    let mut out: Vec<u32> = (0..=qb)
        .filter(|&b| mandatory(b))
        .map(|b| b as u32)
        .chain(ranked.into_iter().map(|b| b as u32))
        .collect();
    out.sort_unstable();
    out
}

/// Mean-pool the keys of a chunk's KV view into per-block per-KV-head
/// vectors: block `b`, head `g` gets the mean of key rows
/// `b·ab ..< min((b+1)·ab, pos+t)`. Cached rows come from `k_cache`
/// (layout `[s, nkv, dh]`, rows `0..pos` valid), fresh rows from
/// `k_new` (layout `[t, nkv, dh]`, already roped). Returns
/// `[n_blocks, nkv, dh]` row-major.
#[allow(clippy::too_many_arguments)]
pub fn pool_keys(k_cache: &[f32], k_new: &[f32], pos: usize, t: usize,
                 nkv: usize, dh: usize, ab: usize) -> Vec<f32> {
    let n_keys = pos + t;
    let n_blocks = n_keys.div_ceil(ab);
    let mut out = vec![0.0f32; n_blocks * nkv * dh];
    for b in 0..n_blocks {
        let lo = b * ab;
        let hi = ((b + 1) * ab).min(n_keys);
        let inv = 1.0 / (hi - lo) as f32;
        for j in lo..hi {
            let row = if j < pos {
                &k_cache[j * nkv * dh..(j + 1) * nkv * dh]
            } else {
                let jr = j - pos;
                &k_new[jr * nkv * dh..(jr + 1) * nkv * dh]
            };
            let dst = &mut out[b * nkv * dh..(b + 1) * nkv * dh];
            for (o, &v) in dst.iter_mut().zip(row.iter()) {
                *o += v * inv;
            }
        }
    }
    out
}

/// Mean-pool a chunk's roped queries (`[t, nh·dh]`) into per-block
/// per-head vectors, `[t/ab, nh, dh]` row-major.
pub fn pool_queries(q: &[f32], t: usize, nh: usize, dh: usize, ab: usize)
                    -> Vec<f32> {
    assert_eq!(t % ab, 0, "query rows must fill whole blocks");
    let n_blocks = t / ab;
    let mut out = vec![0.0f32; n_blocks * nh * dh];
    let inv = 1.0 / ab as f32;
    for b in 0..n_blocks {
        for r in b * ab..(b + 1) * ab {
            let row = &q[r * nh * dh..(r + 1) * nh * dh];
            let dst = &mut out[b * nh * dh..(b + 1) * nh * dh];
            for (o, &v) in dst.iter_mut().zip(row.iter()) {
                *o += v * inv;
            }
        }
    }
    out
}

/// Build the block-selection plan for one chunk of `t` query rows at
/// absolute position `pos`: `plan[lqb][h]` is the ascending list of
/// key-block indices query block `lqb` (local to this chunk) attends
/// through head `h`. `pos` and `t` must both be multiples of the
/// attention block size `ab` — the engine only names attention-sparse
/// executables for aligned full prefill blocks, whose positions are
/// always block multiples.
#[allow(clippy::too_many_arguments)]
pub fn plan(q: &[f32], k_cache: &[f32], k_new: &[f32], pos: usize,
            t: usize, nh: usize, nkv: usize, dh: usize, ab: usize,
            drop: f64) -> Vec<Vec<Vec<u32>>> {
    assert!(ab > 0, "attention block size must be positive");
    assert_eq!(pos % ab, 0, "chunk start must be block-aligned");
    assert_eq!(t % ab, 0, "chunk length must fill whole blocks");
    let group = nh / nkv;
    let pooled_k = pool_keys(k_cache, k_new, pos, t, nkv, dh, ab);
    let pooled_q = pool_queries(q, t, nh, dh, ab);
    let n_qb = t / ab;
    let mut out = Vec::with_capacity(n_qb);
    for lqb in 0..n_qb {
        let qb = pos / ab + lqb; // absolute query-block index
        let mut heads = Vec::with_capacity(nh);
        for h in 0..nh {
            let g = h / group;
            let qv = &pooled_q
                [(lqb * nh + h) * dh..(lqb * nh + h + 1) * dh];
            let scores: Vec<f32> = (0..=qb)
                .map(|b| {
                    let kv = &pooled_k
                        [(b * nkv + g) * dh..(b * nkv + g + 1) * dh];
                    qv.iter().zip(kv.iter()).map(|(a, b)| a * b).sum()
                })
                .collect();
            heads.push(select_blocks(&scores, qb, drop));
        }
        out.push(heads);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn rand_scores(r: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (r.f64() * 8.0 - 4.0) as f32).collect()
    }

    /// Causality: no selected block ever exceeds the query block.
    #[test]
    fn prop_selection_is_causal() {
        check("attn-select-causal", 300, |r| {
            let qb = r.range(0, 40);
            let drop = r.f64();
            let scores = rand_scores(r, qb + 1);
            let sel = select_blocks(&scores, qb, drop);
            crate::prop_assert!(
                sel.iter().all(|&b| (b as usize) <= qb),
                "future key block selected: {sel:?} at qb={qb}"
            );
            for w in sel.windows(2) {
                crate::prop_assert!(
                    w[0] < w[1],
                    "not strictly ascending: {sel:?}"
                );
            }
            Ok(())
        });
    }

    /// The sink and local bands survive regardless of scores — even
    /// when every optional block outscores them.
    #[test]
    fn prop_sink_and_local_always_present() {
        check("attn-select-mandatory", 300, |r| {
            let qb = r.range(0, 40);
            let drop = r.f64();
            // adversarial scores: mandatory blocks score worst
            let scores: Vec<f32> = (0..=qb)
                .map(|b| {
                    if b < SINK_BLOCKS || b + LOCAL_BLOCKS > qb {
                        -1e9
                    } else {
                        (r.f64() * 4.0) as f32
                    }
                })
                .collect();
            let sel = select_blocks(&scores, qb, drop);
            for b in 0..SINK_BLOCKS.min(qb + 1) {
                crate::prop_assert!(
                    sel.contains(&(b as u32)),
                    "sink block {b} dropped: {sel:?}"
                );
            }
            for b in (qb + 1).saturating_sub(LOCAL_BLOCKS)..=qb {
                crate::prop_assert!(
                    sel.contains(&(b as u32)),
                    "local block {b} dropped at qb={qb}: {sel:?}"
                );
            }
            Ok(())
        });
    }

    /// drop = 1.0 (keep zero optional blocks) degenerates to exactly
    /// the sink + local band; drop = 0.0 keeps every causal block.
    #[test]
    fn prop_degenerate_drops() {
        check("attn-select-degenerate", 200, |r| {
            let qb = r.range(0, 40);
            let scores = rand_scores(r, qb + 1);
            let all = select_blocks(&scores, qb, 0.0);
            crate::prop_assert!(
                all == (0..=qb as u32).collect::<Vec<_>>(),
                "drop=0 must keep all causal blocks: {all:?}"
            );
            let band = select_blocks(&scores, qb, 1.0);
            let expect: Vec<u32> = (0..=qb)
                .filter(|&b| b < SINK_BLOCKS || b + LOCAL_BLOCKS > qb)
                .map(|b| b as u32)
                .collect();
            crate::prop_assert!(
                band == expect,
                "drop=1 must keep only sink+local: {band:?} vs {expect:?}"
            );
            Ok(())
        });
    }

    /// Selection is a pure function of scores — two invocations agree,
    /// and a plan built from the same inputs is identical. (The kernel
    /// computes plans sequentially before any row-parallel work, so
    /// thread count cannot enter the selection at all; the conformance
    /// suite re-checks the end-to-end claim at threads {1, 4}.)
    #[test]
    fn prop_selection_deterministic() {
        check("attn-select-deterministic", 100, |r| {
            let qb = r.range(0, 30);
            let drop = r.f64();
            let scores = rand_scores(r, qb + 1);
            crate::prop_assert!(
                select_blocks(&scores, qb, drop)
                    == select_blocks(&scores, qb, drop),
                "selection not deterministic"
            );
            Ok(())
        });
    }

    /// Kept-count arithmetic: the selection size is the mandatory band
    /// plus `ceil((1 − drop) · n_optional)` survivors.
    #[test]
    fn prop_keep_count() {
        check("attn-select-count", 200, |r| {
            let qb = r.range(0, 60);
            let drop = r.f64();
            let scores = rand_scores(r, qb + 1);
            let n_mand = (0..=qb)
                .filter(|&b| b < SINK_BLOCKS || b + LOCAL_BLOCKS > qb)
                .count();
            let n_opt = qb + 1 - n_mand;
            let keep = ((1.0 - drop) * n_opt as f64).ceil() as usize;
            let sel = select_blocks(&scores, qb, drop);
            crate::prop_assert!(
                sel.len() == n_mand + keep.min(n_opt),
                "size {} != mandatory {n_mand} + keep {keep}",
                sel.len()
            );
            Ok(())
        });
    }

    /// Plans over a seeded KV view are deterministic and causal, and a
    /// drop of 0.0 covers every causal block for every head.
    #[test]
    fn prop_plan_invariants() {
        check("attn-plan", 40, |r| {
            let (nh, nkv, dh, ab) = (4usize, 2usize, 8usize, 16usize);
            let n_blocks = r.range(1, 5);
            let pos = r.range(0, 4) * ab;
            let t = n_blocks * ab;
            let q: Vec<f32> = (0..t * nh * dh)
                .map(|_| (r.f64() * 2.0 - 1.0) as f32)
                .collect();
            let kc: Vec<f32> = (0..pos * nkv * dh)
                .map(|_| (r.f64() * 2.0 - 1.0) as f32)
                .collect();
            let kn: Vec<f32> = (0..t * nkv * dh)
                .map(|_| (r.f64() * 2.0 - 1.0) as f32)
                .collect();
            let drop = r.f64();
            let p = plan(&q, &kc, &kn, pos, t, nh, nkv, dh, ab, drop);
            let p2 = plan(&q, &kc, &kn, pos, t, nh, nkv, dh, ab, drop);
            crate::prop_assert!(p == p2, "plan not deterministic");
            crate::prop_assert!(p.len() == t / ab, "plan block count");
            for (lqb, heads) in p.iter().enumerate() {
                let qb = pos / ab + lqb;
                crate::prop_assert!(heads.len() == nh, "head count");
                for sel in heads {
                    crate::prop_assert!(
                        sel.iter().all(|&b| (b as usize) <= qb),
                        "plan selected a future block"
                    );
                }
            }
            let full = plan(&q, &kc, &kn, pos, t, nh, nkv, dh, ab, 0.0);
            for (lqb, heads) in full.iter().enumerate() {
                let qb = pos / ab + lqb;
                for sel in heads {
                    crate::prop_assert!(
                        *sel == (0..=qb as u32).collect::<Vec<_>>(),
                        "drop=0 plan must cover all causal blocks"
                    );
                }
            }
            Ok(())
        });
    }
}
