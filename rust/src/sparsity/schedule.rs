//! Layerwise sparsity schedule — paper §3.4 Algorithm 1, re-implemented
//! from the pseudo-code and property-tested. python/compile/calibrate.py
//! holds the twin implementation used at artifact-build time; the two are
//! cross-checked against schedule.json by an integration test.

/// Paper Algorithm 1: allocate per-layer density budgets b_i ∈ (0, 1]
/// proportionally to importance scores s_i, greedily clamping at 1 and
/// redistributing the remainder. `budget` is the mean target density
/// B = 1 - sparsity.
pub fn layerwise_schedule(scores: &[f64], budget: f64) -> Vec<f64> {
    let n = scores.len();
    let mut t = budget * n as f64;
    let mut s_total: f64 = scores.iter().sum();
    let mut out = Vec::with_capacity(n);
    for (i, &s) in scores.iter().enumerate() {
        let b = if s_total > 0.0 {
            (s / s_total * t).min(1.0)
        } else {
            // degenerate (all remaining scores are zero): spread what's
            // left uniformly across the *remaining* layers, not dumped
            // onto the next one
            (t / (n - i) as f64).min(1.0)
        };
        t -= b;
        s_total -= s;
        out.push(b.max(0.0));
    }
    out
}

/// Quantize densities to K = multiples of the kernel tile (ftile),
/// clamped to [ftile, d_ffn] — every emitted K maps to an AOT artifact.
pub fn quantize_densities(densities: &[f64], d_ffn: usize, ftile: usize) -> Vec<usize> {
    densities
        .iter()
        .map(|&b| {
            let tiles = (b * d_ffn as f64 / ftile as f64).round() as i64;
            let tiles = tiles.clamp(1, (d_ffn / ftile) as i64);
            tiles as usize * ftile
        })
        .collect()
}

/// Mean density actually achieved by a quantized schedule.
pub fn achieved_density(layer_k: &[usize], d_ffn: usize) -> f64 {
    if layer_k.is_empty() {
        return 0.0;
    }
    layer_k.iter().map(|&k| k as f64 / d_ffn as f64).sum::<f64>()
        / layer_k.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn uniform_scores_give_uniform_budget() {
        let b = layerwise_schedule(&[1.0, 1.0, 1.0, 1.0], 0.5);
        for x in b {
            assert!((x - 0.5).abs() < 1e-12);
        }
    }

    #[test]
    fn important_layers_get_more() {
        let b = layerwise_schedule(&[4.0, 1.0, 1.0, 1.0], 0.5);
        assert!(b[0] > b[1]);
        assert!(b[0] <= 1.0);
    }

    #[test]
    fn clamping_redistributes() {
        // layer 0 wants >1; the excess must flow to later layers
        let b = layerwise_schedule(&[100.0, 1.0, 1.0, 1.0], 0.7);
        assert!((b[0] - 1.0).abs() < 1e-12);
        let mean: f64 = b.iter().sum::<f64>() / 4.0;
        assert!((mean - 0.7).abs() < 1e-9, "budget conserved, mean={mean}");
    }

    #[test]
    fn zero_score_tail_spreads_remainder_uniformly() {
        // regression: the degenerate branch used to divide by 1.0,
        // dumping the whole leftover budget on the first zero-score
        // layer and starving the rest
        let b = layerwise_schedule(&[2.0, 0.0, 0.0, 0.0], 0.5);
        assert!((b[0] - 1.0).abs() < 1e-12, "dominant layer clamps at 1");
        for (i, &x) in b.iter().enumerate().skip(1) {
            assert!(
                (x - 1.0 / 3.0).abs() < 1e-12,
                "zero-score layer {i} gets an equal remainder share, \
                 got {x}"
            );
        }
        let total: f64 = b.iter().sum();
        assert!((total - 0.5 * 4.0).abs() < 1e-9, "budget conserved");

        // all-zero scores degenerate to the uniform schedule
        let u = layerwise_schedule(&[0.0, 0.0, 0.0], 0.4);
        for &x in &u {
            assert!((x - 0.4).abs() < 1e-12);
        }
        let total: f64 = u.iter().sum();
        assert!((total - 0.4 * 3.0).abs() < 1e-9);
    }

    #[test]
    fn prop_budget_conservation_and_bounds() {
        check("alg1-invariants", 300, |r| {
            let n = r.range(1, 33);
            let scores: Vec<f64> =
                (0..n).map(|_| r.f64() * 10.0 + 1e-6).collect();
            let budget = 0.05 + r.f64() * 0.9;
            let b = layerwise_schedule(&scores, budget);
            crate::prop_assert!(b.len() == n, "len");
            for (i, &x) in b.iter().enumerate() {
                crate::prop_assert!(
                    (0.0..=1.0 + 1e-12).contains(&x),
                    "b[{i}]={x} out of range"
                );
            }
            // budget conservation: sum(b) == B*n unless everything
            // saturates; always sum(b) <= B*n + eps
            let total: f64 = b.iter().sum();
            let target = budget * n as f64;
            crate::prop_assert!(
                total <= target + 1e-9,
                "overspent: {total} > {target}"
            );
            // Exact conservation only when no layer clamps at 1: the
            // paper's greedy under-allocates when trailing layers clamp.
            let any_clamped = b.iter().any(|&x| x >= 1.0 - 1e-12);
            if !any_clamped {
                crop_conserved(total, target)?;
            }
            Ok(())
        });

        fn crop_conserved(total: f64, target: f64) -> Result<(), String> {
            if (total - target).abs() < 1e-6 {
                Ok(())
            } else {
                Err(format!("not conserved: {total} vs {target}"))
            }
        }
    }

    #[test]
    fn prop_monotone_in_importance() {
        // with no clamping, a more important layer never gets less
        check("alg1-monotone", 200, |r| {
            let n = r.range(2, 17);
            let scores: Vec<f64> = (0..n).map(|_| r.f64() + 0.01).collect();
            let b = layerwise_schedule(&scores, 0.3); // low budget: no clamp
            for i in 0..n {
                for j in 0..n {
                    if scores[i] > scores[j] && b[i] + 1e-9 < b[j] {
                        // Alg 1 is order-dependent; monotonicity holds
                        // among *unclamped* layers only when processed in
                        // order. Check the proportionality for adjacent
                        // unclamped layers instead.
                    }
                }
            }
            // weaker invariant that genuinely holds: nothing clamped at
            // budget 0.3 unless score dominates hugely; all in (0,1]
            crate::prop_assert!(
                b.iter().all(|&x| x > 0.0 && x <= 1.0),
                "bounds"
            );
            Ok(())
        });
    }

    #[test]
    fn quantize_respects_grid() {
        let k = quantize_densities(&[0.49, 0.74, 1.0, 0.01], 512, 64);
        assert_eq!(k, vec![256, 384, 512, 64]);
        for x in &k {
            assert_eq!(x % 64, 0);
        }
    }

    #[test]
    fn prop_quantize_bounds() {
        check("quantize-bounds", 200, |r| {
            let d_ffn = 512usize;
            let ftile = [32, 64, 128][r.range(0, 3)];
            let n = r.range(1, 13);
            let dens: Vec<f64> = (0..n).map(|_| r.f64()).collect();
            let ks = quantize_densities(&dens, d_ffn, ftile);
            for &k in &ks {
                crate::prop_assert!(
                    k >= ftile && k <= d_ffn && k % ftile == 0,
                    "k={k} ftile={ftile}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn achieved_density_sane() {
        assert!((achieved_density(&[256, 256], 512) - 0.5).abs() < 1e-12);
        assert_eq!(achieved_density(&[], 512), 0.0);
    }

    /// The budget contract of the full Algorithm-1 → quantizer pipeline:
    /// the quantized schedule's achieved mean density never exceeds the
    /// requested budget by more than one ftile's worth of density, and
    /// no single layer drifts more than one ftile from its unquantized
    /// allocation.
    #[test]
    fn prop_quantized_schedule_respects_budget() {
        check("quantize-budget", 300, |r| {
            let d_ffn = [256usize, 512, 1024][r.range(0, 3)];
            let ftile = [32usize, 64, 128][r.range(0, 3)];
            let n = r.range(1, 33);
            // scores with a zero tail in ~1/3 of cases, to route through
            // the degenerate uniform-spread branch fixed in PR 2
            let zero_tail = r.bool(0.33);
            let scores: Vec<f64> = (0..n)
                .map(|l| {
                    if zero_tail && l >= n / 2 {
                        0.0
                    } else {
                        r.f64() * 10.0
                    }
                })
                .collect();
            let budget = 0.05 + r.f64() * 0.9;
            let dens = layerwise_schedule(&scores, budget);
            let ks = quantize_densities(&dens, d_ffn, ftile);
            crate::prop_assert!(ks.len() == n, "len");
            // per-layer: within one ftile of the unquantized density
            for (i, (&k, &b)) in ks.iter().zip(dens.iter()).enumerate() {
                let want = b * d_ffn as f64;
                crate::prop_assert!(
                    (k as f64 - want).abs() <= ftile as f64 + 1e-9,
                    "layer {i}: K={k} drifts more than one ftile from \
                     unquantized {want}"
                );
            }
            // mean: achieved ≤ budget + one tile of density
            let achieved = achieved_density(&ks, d_ffn);
            let slack = ftile as f64 / d_ffn as f64;
            crate::prop_assert!(
                achieved <= budget + slack + 1e-9,
                "achieved {achieved} exceeds budget {budget} by more \
                 than one ftile ({slack})"
            );
            Ok(())
        });
    }

    /// Round-trip regression through the zero-score branch: the spread
    /// remainder must quantize onto the grid and stay within budget,
    /// exactly as the all-positive path does.
    #[test]
    fn zero_score_schedule_roundtrips_through_quantizer() {
        let (d_ffn, ftile) = (256usize, 32usize);
        for scores in [
            vec![2.0, 0.0, 0.0, 0.0],
            vec![0.0, 0.0, 0.0],
            vec![0.0, 5.0, 0.0, 0.0, 0.0, 0.0],
        ] {
            for budget in [0.3, 0.5, 0.7] {
                let dens = layerwise_schedule(&scores, budget);
                let total: f64 = dens.iter().sum();
                assert!(
                    total <= budget * scores.len() as f64 + 1e-9,
                    "overspent: {total}"
                );
                let ks = quantize_densities(&dens, d_ffn, ftile);
                for &k in &ks {
                    assert!(k % ftile == 0 && (ftile..=d_ffn).contains(&k));
                }
                let achieved = achieved_density(&ks, d_ffn);
                assert!(
                    achieved <= budget + ftile as f64 / d_ffn as f64 + 1e-9,
                    "achieved {achieved} vs budget {budget} \
                     (scores {scores:?})"
                );
            }
        }
    }
}
