//! FastForward sparsity machinery: the layerwise schedule (Algorithm 1),
//! expert mask selection, block-sparse attention selection, speculative
//! prefill token selection, and the baseline predictors from the
//! paper's ablations (per-block-dynamic oracle, GRIFFIN
//! first-block-static, CATS thresholding).

pub mod attn;
pub mod masks;
pub mod schedule;
pub mod tokens;

pub use masks::{top_k_indices, ExpertSource};
pub use schedule::{layerwise_schedule, quantize_densities};
