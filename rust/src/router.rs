//! Request router: admission control, bounded class-aware queueing,
//! backpressure, and least-loaded dispatch across executor replicas.
//!
//! The router sits between the (multi-threaded) HTTP front-end and the
//! executor pool. Admission enforces (a) a per-replica queue-depth bound
//! and (b) KV-memory feasibility via the paged allocator, rejecting
//! early (HTTP 429) rather than letting latency collapse. Admitted
//! requests are dispatched to the replica with the lowest outstanding
//! load, where load is the sum of per-request cost estimates — queue
//! depth weighted by estimated prefill blocks plus discounted decode
//! steps, from the [`LoadEstimator`] (optionally calibrated against the
//! FLOP cost model).
//!
//! **Streaming-first:** every request carries a [`TokenEvent`] channel,
//! not a one-shot response slot. The executor emits `First` when prefill
//! completes, one `Token` per decoded token, and a terminal `Done`
//! carrying the full [`Response`]. One-shot callers simply drain the
//! channel with [`Response::collect`]; the HTTP server forwards the same
//! events as SSE frames. A [`CancelToken`] rides along so a client
//! disconnect can abort the session and release its KV pages mid-flight.
//!
//! **SLO classes:** requests declare an [`SloClass`] (interactive or
//! batch, optionally with a completion deadline). Each replica keeps one queue
//! per class and pops interactive work first; the batcher's scheduler
//! additionally preempts batch prefill while interactive work is pending
//! (see `batcher.rs` and docs/SCHEDULING.md).
//!
//! The router also owns the two resources shared by every replica: the
//! paged KV allocator and the block-granular [`PrefixCache`], so a
//! prefix computed on one replica is adoptable by all of them.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::cost::CostModel;
use crate::engine::SparsityConfig;
use crate::kvcache::{PagedAllocator, PrefixCache};
use crate::metrics::Metrics;

/// Service-level objective class of a request.
///
/// Interactive requests are latency-sensitive: replicas pop them first
/// and the scheduler preempts batch prefill on their behalf. Batch
/// requests are throughput traffic that absorbs the induced delay.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SloClass {
    /// Latency-sensitive traffic (the default): prioritized admission,
    /// protected TTFT and inter-token latency.
    #[default]
    Interactive,
    /// Throughput traffic: yields the engine to interactive work and is
    /// preempted mid-prefill when interactive SLOs are at risk.
    Batch,
}

impl SloClass {
    /// Whether this is the interactive (latency-sensitive) class.
    pub fn is_interactive(self) -> bool {
        matches!(self, SloClass::Interactive)
    }

    /// Stable label used in metrics and the HTTP API.
    pub fn label(self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Batch => "batch",
        }
    }

    /// Parse an API string ("interactive" / "batch").
    pub fn parse(s: &str) -> Option<SloClass> {
        match s {
            "interactive" => Some(SloClass::Interactive),
            "batch" => Some(SloClass::Batch),
            _ => None,
        }
    }
}

/// Shared cancellation flag for one request.
///
/// Cloned between the submitter (which flips it when the client goes
/// away) and the executor (which checks it every scheduler iteration
/// and releases the session's KV pages on cancellation). Purely
/// advisory — cancelling after completion is a no-op.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// Fresh, un-cancelled token.
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation (idempotent).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    /// Whether cancellation has been requested.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// One event on a request's stream, emitted by the executor in order:
/// exactly one `First`, zero or more `Token`s, exactly one terminal
/// `Done` (failed requests may skip straight to `Done`).
///
/// The streaming client path in full, with the executor side played by
/// hand (no engine needed):
///
/// ```
/// use std::sync::mpsc::channel;
/// use fastforward::router::{Response, TokenEvent};
///
/// let (tx, rx) = channel();
/// // executor side: first-token marker, one token, terminal response
/// tx.send(TokenEvent::First { ttft_ms: 12.5, reused_blocks: 0 }).unwrap();
/// tx.send(TokenEvent::Token { token: b'h' as i32, text: "h".into() })
///     .unwrap();
/// let mut done = Response::failed(7, String::new());
/// done.error = None;
/// done.text = "h".into();
/// done.tokens = 1;
/// tx.send(TokenEvent::Done(done)).unwrap();
///
/// // client side: stream tokens, then keep the final response
/// let resp = Response::collect(&rx).expect("terminal Done event");
/// assert_eq!(resp.text, "h");
/// assert_eq!(resp.tokens, 1);
/// ```
#[derive(Debug, Clone)]
pub enum TokenEvent {
    /// Prefill completed; decoding begins. Emitted exactly when TTFT is
    /// recorded (the paper's definition: first decode logits produced).
    First {
        /// Time to first token in milliseconds.
        ttft_ms: f64,
        /// Prefill blocks adopted from the prefix cache (0 = cold).
        reused_blocks: usize,
    },
    /// One decoded token.
    Token {
        /// Token id (byte-level vocabulary).
        token: i32,
        /// UTF-8 text completed by this token. May be empty while a
        /// multi-byte character is still being assembled.
        text: String,
    },
    /// Terminal event: the complete response (success or failure).
    /// Always the last event on the channel.
    Done(Response),
}

/// A queued generation request.
pub struct Request {
    /// Router-assigned id (monotonic per process).
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Maximum tokens to decode.
    pub max_tokens: usize,
    /// Sparsity configuration the request runs under.
    pub cfg: SparsityConfig,
    /// SLO class (scheduling priority).
    pub class: SloClass,
    /// Optional completion deadline in milliseconds from submission;
    /// the scheduler preempts batch prefill when the cost model
    /// projects a miss (interactive requests only).
    pub deadline_ms: Option<f64>,
    /// When the request entered the router (queue-delay accounting).
    pub submitted: Instant,
    /// Cooperative cancellation (client disconnect).
    pub cancel: CancelToken,
    /// Channel the request's [`TokenEvent`] stream is delivered on.
    pub events: Sender<TokenEvent>,
    /// Whether the queue-delay histogram already sampled this request
    /// (set at first admission, so an ejected-and-readmitted request
    /// is not double-counted).
    pub(crate) delay_sampled: bool,
}

/// Submission options beyond the prompt itself (class, deadline,
/// cancellation). `SubmitOpts::default()` is an interactive request
/// with no deadline and a fresh cancel token.
#[derive(Debug, Clone, Default)]
pub struct SubmitOpts {
    /// SLO class of the request.
    pub class: SloClass,
    /// Optional completion deadline in milliseconds from submission.
    pub deadline_ms: Option<f64>,
    /// Cancellation token shared with the submitter.
    pub cancel: CancelToken,
}

/// A finished (or failed) generation, carried by [`TokenEvent::Done`].
#[derive(Debug, Clone)]
pub struct Response {
    /// The id returned by [`Router::submit`].
    pub id: u64,
    /// Decoded generation (empty on error).
    pub text: String,
    /// Number of generated tokens.
    pub tokens: usize,
    /// Time to first token in milliseconds (prefill completion).
    pub ttft_ms: f64,
    /// Mean decode time per output token in milliseconds.
    pub tpot_ms: f64,
    /// End-to-end latency in milliseconds (admission to completion).
    pub e2e_ms: f64,
    /// Prefill blocks adopted from the prefix cache (0 = cold prefill).
    pub reused_blocks: usize,
    /// Error description when the request failed.
    pub error: Option<String>,
}

impl Response {
    /// An error response for a request that produced no output.
    pub fn failed(id: u64, error: String) -> Self {
        Response {
            id,
            text: String::new(),
            tokens: 0,
            ttft_ms: 0.0,
            tpot_ms: 0.0,
            e2e_ms: 0.0,
            reused_blocks: 0,
            error: Some(error),
        }
    }

    /// Drain a request's event stream to its terminal [`Response`] —
    /// the one-shot adapter over the streaming path. Returns `None`
    /// when the executor dropped the channel without a `Done` event
    /// (executor thread died).
    pub fn collect(rx: &Receiver<TokenEvent>) -> Option<Response> {
        loop {
            match rx.recv() {
                Ok(TokenEvent::Done(resp)) => return Some(resp),
                Ok(_) => continue,
                Err(_) => return None,
            }
        }
    }

    /// [`Response::collect`] with a per-event timeout: `None` on
    /// timeout or a dropped channel.
    pub fn collect_timeout(rx: &Receiver<TokenEvent>,
                           timeout: std::time::Duration) -> Option<Response> {
        loop {
            match rx.recv_timeout(timeout) {
                Ok(TokenEvent::Done(resp)) => return Some(resp),
                Ok(_) => continue,
                Err(_) => return None,
            }
        }
    }
}

/// Rejection reasons surfaced to clients.
#[derive(Debug, Clone, PartialEq)]
pub enum Reject {
    /// The least-loaded replica's queue is at the configured bound.
    QueueFull,
    /// prompt + max_tokens exceeds the model context.
    PromptTooLong {
        /// Requested total positions.
        len: usize,
        /// Model maximum.
        max: usize,
    },
    /// The paged KV pool cannot hold the request right now.
    KvExhausted,
    /// Every executor replica is dead (engine failed to load).
    Unavailable,
}

/// Translates a request into abstract scheduling cost.
///
/// A full prefill block costs 1; ragged-tail tokens and decode steps —
/// both of which execute as T=1 steps — each cost `decode_unit`. The
/// default `decode_unit` of 1.0 models the dispatch-bound CPU engine,
/// where a T=1 step costs about as much as a block step;
/// [`LoadEstimator::from_cost_model`] calibrates it to the FLOP ratio
/// instead, which is the right weighting for compute-bound hardware.
#[derive(Debug, Clone, Copy)]
pub struct LoadEstimator {
    /// Prefill block size in tokens.
    pub block: usize,
    /// Cost of one T=1 step (tail token or decode step) relative to one
    /// prefill block.
    pub decode_unit: f64,
}

impl LoadEstimator {
    /// Step-count estimator at the given block size (decode step ≈ one
    /// block step; right for the dispatch-bound CPU engine).
    pub fn new(block: usize) -> Self {
        LoadEstimator {
            block: block.max(1),
            decode_unit: 1.0,
        }
    }

    /// FLOP-calibrated estimator: one decode step is weighted by the
    /// cost model's single-token/full-block FLOP ratio at a
    /// representative context (1024 tokens).
    pub fn from_cost_model(cm: &CostModel) -> Self {
        let block_flops = cm.layer_flops(cm.block, 1024, cm.d_ffn, false)
            .total();
        let token_flops = cm.layer_flops(1, 1024, cm.d_ffn, false).total();
        LoadEstimator {
            block: cm.block.max(1),
            decode_unit: if block_flops > 0.0 {
                token_flops / block_flops
            } else {
                1.0
            },
        }
    }

    /// Estimated cost of a request in prefill-block units.
    pub fn cost(&self, prompt_len: usize, max_tokens: usize) -> f64 {
        let full_blocks = prompt_len / self.block;
        let tail = prompt_len % self.block;
        full_blocks as f64 + self.decode_unit * (tail + max_tokens) as f64
    }
}

struct ReplicaInner {
    /// Interactive-class FIFO — always popped before `batch`.
    interactive: VecDeque<Request>,
    /// Batch-class FIFO.
    batch: VecDeque<Request>,
    queued_cost: f64,
    inflight_cost: f64,
    closed: bool,
    dead: bool,
}

impl ReplicaInner {
    fn queue_len(&self) -> usize {
        self.interactive.len() + self.batch.len()
    }
}

/// One executor replica's work queues and load accounting.
///
/// Created by the router ([`Router::new_pooled`]); each replica is owned
/// by exactly one executor thread, which pops work with
/// [`Replica::pop_blocking`] / [`Replica::pop_up_to`] and reports
/// completions with [`Replica::complete`]. The replica keeps one FIFO
/// per [`SloClass`] and always pops interactive work first. Cost
/// accounting mirrors the request lifecycle: submit adds to `queued`,
/// pop moves `queued` → `inflight`, complete removes from `inflight`.
pub struct Replica {
    id: usize,
    estimator: LoadEstimator,
    max_queue: usize,
    inner: Mutex<ReplicaInner>,
    notify: Condvar,
}

impl Replica {
    fn new(id: usize, estimator: LoadEstimator, max_queue: usize) -> Self {
        Replica {
            id,
            estimator,
            max_queue,
            inner: Mutex::new(ReplicaInner {
                interactive: VecDeque::new(),
                batch: VecDeque::new(),
                queued_cost: 0.0,
                inflight_cost: 0.0,
                closed: false,
                dead: false,
            }),
            notify: Condvar::new(),
        }
    }

    /// Index of this replica in the pool ([0, replica_count)).
    pub fn id(&self) -> usize {
        self.id
    }

    /// Requests currently queued (both classes, not yet popped).
    pub fn queue_len(&self) -> usize {
        self.inner.lock().unwrap().queue_len()
    }

    /// Outstanding load: queued + in-flight cost estimates.
    pub fn load(&self) -> f64 {
        let g = self.inner.lock().unwrap();
        g.queued_cost + g.inflight_cost
    }

    /// Whether the replica's executor failed permanently.
    pub fn is_dead(&self) -> bool {
        self.inner.lock().unwrap().dead
    }

    /// Enqueue a request. Refused with a reason when the replica is
    /// closed/dead (nothing may land after the dead-drain and hang its
    /// client) or when the queue is at its bound — enforced here, under
    /// the same lock as the enqueue, so concurrent submits cannot
    /// overshoot `max_queue` between check and push.
    fn push(&self, req: Request)
            -> std::result::Result<(), (Request, Reject)> {
        let cost = self.estimator.cost(req.prompt.len(), req.max_tokens);
        let mut g = self.inner.lock().unwrap();
        if g.dead || g.closed {
            return Err((req, Reject::Unavailable));
        }
        if g.queue_len() >= self.max_queue {
            return Err((req, Reject::QueueFull));
        }
        g.queued_cost += cost;
        match req.class {
            SloClass::Interactive => g.interactive.push_back(req),
            SloClass::Batch => g.batch.push_back(req),
        }
        drop(g);
        self.notify.notify_one();
        Ok(())
    }

    fn take_front(g: &mut ReplicaInner, est: &LoadEstimator)
                  -> Option<Request> {
        let req = g
            .interactive
            .pop_front()
            .or_else(|| g.batch.pop_front())?;
        let cost = est.cost(req.prompt.len(), req.max_tokens);
        g.queued_cost = (g.queued_cost - cost).max(0.0);
        g.inflight_cost += cost;
        Some(req)
    }

    /// Blocking pop for the executor thread (interactive first); None
    /// once closed and empty.
    pub fn pop_blocking(&self) -> Option<Request> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = Self::take_front(&mut g, &self.estimator) {
                return Some(r);
            }
            if g.closed {
                return None;
            }
            g = self.notify.wait(g).unwrap();
        }
    }

    /// Non-blocking drain of up to `n` requests (executor admission),
    /// interactive class first.
    pub fn pop_up_to(&self, n: usize) -> Vec<Request> {
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::new();
        while out.len() < n {
            match Self::take_front(&mut g, &self.estimator) {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }

    /// Return a popped request to the *front* of its class queue:
    /// admission hit transient KV pressure (or a preempted prefill was
    /// ejected) and will retry once pages free up. Moves the cost
    /// estimate back from in-flight to queued.
    pub fn requeue(&self, req: Request) {
        let cost = self.estimator.cost(req.prompt.len(), req.max_tokens);
        let mut g = self.inner.lock().unwrap();
        g.inflight_cost = (g.inflight_cost - cost).max(0.0);
        g.queued_cost += cost;
        match req.class {
            SloClass::Interactive => g.interactive.push_front(req),
            SloClass::Batch => g.batch.push_front(req),
        }
    }

    /// Report a popped request as finished (success or failure),
    /// removing its cost estimate from the in-flight load.
    pub fn complete(&self, prompt_len: usize, max_tokens: usize) {
        let cost = self.estimator.cost(prompt_len, max_tokens);
        let mut g = self.inner.lock().unwrap();
        g.inflight_cost = (g.inflight_cost - cost).max(0.0);
    }

    /// Stop accepting work and wake the executor so it can drain.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.notify.notify_all();
    }

    /// Mark the replica permanently failed (the router stops dispatching
    /// to it) and fail every queued request with `error`. Prefer
    /// [`Router::fail_over`], which re-routes the drained queue to the
    /// surviving replicas instead of erroring it.
    pub fn mark_dead(&self, error: &str) {
        for req in self.drain_dead() {
            let _ = req.events.send(TokenEvent::Done(Response::failed(
                req.id,
                error.to_string(),
            )));
        }
    }

    /// Flip the replica dead+closed and take its queued (never-popped)
    /// requests. The caller decides their fate — [`Replica::mark_dead`]
    /// fails them, [`Router::fail_over`] re-routes them.
    pub(crate) fn drain_dead(&self) -> Vec<Request> {
        let drained: Vec<Request> = {
            let mut g = self.inner.lock().unwrap();
            g.dead = true;
            g.closed = true;
            g.queued_cost = 0.0;
            let inner = &mut *g;
            inner
                .interactive
                .drain(..)
                .chain(inner.batch.drain(..))
                .collect()
        };
        self.notify.notify_all();
        drained
    }
}

/// Thread-safe router handle shared by the HTTP front-end and the
/// executor pool.
pub struct Router {
    replicas: Vec<Arc<Replica>>,
    next_id: Mutex<u64>,
    estimator: LoadEstimator,
    /// Per-replica queue-depth bound enforced at admission.
    pub max_queue: usize,
    /// Maximum prompt + generation positions per request.
    pub max_ctx: usize,
    /// Shared paged KV allocator (admission control + prefix residency).
    pub kv_pool: Mutex<PagedAllocator>,
    /// Shared block-granular prefix cache (disabled at zero budget).
    pub prefix_cache: Mutex<PrefixCache>,
    /// Shared metrics registry.
    pub metrics: Arc<Metrics>,
}

impl Router {
    /// Single-replica router with the prefix cache disabled — the legacy
    /// constructor used by the single-executor stack and tests.
    pub fn new(max_queue: usize, max_ctx: usize, kv_pages: usize,
               page_size: usize, metrics: Arc<Metrics>) -> Self {
        Self::new_pooled(
            max_queue,
            max_ctx,
            kv_pages,
            page_size,
            metrics,
            1,
            LoadEstimator::new(page_size),
            0,
        )
    }

    /// Full constructor: `n_replicas` executor queues and a prefix cache
    /// of `prefix_cache_bytes` (0 disables prefix reuse). The prefix
    /// cache's block granularity is taken from `estimator.block`, which
    /// must equal the engine's prefill block size.
    #[allow(clippy::too_many_arguments)]
    pub fn new_pooled(max_queue: usize, max_ctx: usize, kv_pages: usize,
                      page_size: usize, metrics: Arc<Metrics>,
                      n_replicas: usize, estimator: LoadEstimator,
                      prefix_cache_bytes: usize) -> Self {
        let n = n_replicas.max(1);
        metrics.ensure_replicas(n);
        Router {
            replicas: (0..n)
                .map(|i| Arc::new(Replica::new(i, estimator, max_queue)))
                .collect(),
            next_id: Mutex::new(1),
            estimator,
            max_queue,
            max_ctx,
            kv_pool: Mutex::new(PagedAllocator::new(kv_pages, page_size)),
            prefix_cache: Mutex::new(PrefixCache::new(
                estimator.block,
                prefix_cache_bytes,
            )),
            metrics,
        }
    }

    /// Number of executor replicas.
    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Handle to replica `i` (panics when out of range).
    pub fn replica(&self, i: usize) -> Arc<Replica> {
        self.replicas[i].clone()
    }

    /// The request-cost estimator used for dispatch.
    pub fn estimator(&self) -> LoadEstimator {
        self.estimator
    }

    /// Admit an interactive request with default options — see
    /// [`Router::submit_with`].
    pub fn submit(&self, prompt: Vec<i32>, max_tokens: usize,
                  cfg: SparsityConfig, events: Sender<TokenEvent>)
                  -> Result<u64, Reject> {
        self.submit_with(prompt, max_tokens, cfg, SubmitOpts::default(),
                         events)
    }

    /// Admit a request or reject with a reason.
    ///
    /// Admission checks context bound, KV feasibility and the target
    /// replica's queue bound, then dispatches to the least-loaded alive
    /// replica. The executor streams [`TokenEvent`]s on `events`; the
    /// submitter keeps `opts.cancel` (or a clone) to abort the request
    /// on client disconnect.
    pub fn submit_with(&self, prompt: Vec<i32>, max_tokens: usize,
                       cfg: SparsityConfig, opts: SubmitOpts,
                       events: Sender<TokenEvent>) -> Result<u64, Reject> {
        let total = prompt.len() + max_tokens;
        if total > self.max_ctx {
            self.metrics.record_rejection();
            return Err(Reject::PromptTooLong {
                len: total,
                max: self.max_ctx,
            });
        }
        {
            // poison-recovering locks throughout: a panicking executor
            // must not turn every later admission into a PoisonError
            // cascade (the page accounting is repaired by release
            // sweeps, not by the panicked critical section)
            let pool = crate::util::sync::lock_recover(&self.kv_pool);
            if !pool.can_allocate(total) {
                // Live requests outrank cached residency: reclaim
                // unpinned prefix entries before rejecting. Lock order
                // matches the batcher's insert site (prefix before
                // pool), so re-acquire in that order.
                drop(pool);
                let mut pc =
                    crate::util::sync::lock_recover(&self.prefix_cache);
                let mut pool =
                    crate::util::sync::lock_recover(&self.kv_pool);
                let needed = pool.pages_for(total);
                pc.evict_for(needed, &mut pool);
                if !pool.can_allocate(total) {
                    self.metrics.record_rejection();
                    return Err(Reject::KvExhausted);
                }
            }
        }
        let replica = match self.least_loaded() {
            Ok(r) => r,
            Err(reject) => {
                self.metrics.record_rejection();
                return Err(reject);
            }
        };
        let id = {
            let mut g = self.next_id.lock().unwrap();
            let id = *g;
            *g += 1;
            id
        };
        if let Err((_req, reject)) = replica.push(Request {
            id,
            prompt,
            max_tokens,
            cfg,
            class: opts.class,
            deadline_ms: opts.deadline_ms,
            submitted: Instant::now(),
            cancel: opts.cancel,
            events,
            delay_sampled: false,
        }) {
            // the replica died or filled between least_loaded() and
            // push(); the request was never enqueued, so reject instead
            // of letting the client wait on a queue nobody will drain
            self.metrics.record_rejection();
            return Err(reject);
        }
        self.metrics.record_replica_dispatch(replica.id());
        Ok(id)
    }

    /// The alive replica with the lowest outstanding load *among those
    /// with queue room* (ties break toward the lowest id). Replicas at
    /// their queue bound are skipped, so cost-based load and queue
    /// depth diverging (one replica full of tiny requests, another of
    /// huge ones) never causes spurious QueueFull while capacity
    /// exists elsewhere. The pick itself is the shared
    /// [`crate::cluster::policy::least_loaded`] rule — the same policy
    /// the cluster front applies across worker processes.
    fn least_loaded(&self) -> std::result::Result<Arc<Replica>, Reject> {
        use crate::cluster::policy::{self, Candidate, PickError};
        let picked = policy::least_loaded(self.replicas.iter().map(|r| {
            Candidate {
                idx: r.id(),
                alive: !r.is_dead(),
                has_room: r.queue_len() < self.max_queue,
                load: r.load(),
            }
        }));
        match picked {
            Ok(i) => Ok(self.replicas[i].clone()),
            Err(PickError::Saturated) => Err(Reject::QueueFull),
            Err(PickError::NoneAlive) => Err(Reject::Unavailable),
        }
    }

    /// Whether any replica is still accepting work — the `/readyz`
    /// predicate (a server whose every executor died is up but not
    /// ready).
    pub fn has_alive_replica(&self) -> bool {
        self.replicas.iter().any(|r| !r.is_dead())
    }

    /// Mark replica `id` dead and **re-route** its queued requests to
    /// the surviving replicas instead of failing them: each drained
    /// request is re-admitted through the least-loaded pick, and only
    /// requests no alive replica can take (none left, or all at their
    /// bound) fail with `error`. Returns `(rerouted, failed)` counts;
    /// both are also recorded as `ff_failover_*` metrics.
    pub fn fail_over(&self, id: usize, error: &str) -> (usize, usize) {
        let drained = self.replicas[id].drain_dead();
        let (mut rerouted, mut failed) = (0usize, 0usize);
        for req in drained {
            // re-pick per request so re-routed load spreads instead of
            // dogpiling the single least-loaded survivor
            let target = self.least_loaded();
            let req = match target {
                Ok(replica) => match replica.push(req) {
                    Ok(()) => {
                        self.metrics.record_replica_dispatch(replica.id());
                        rerouted += 1;
                        continue;
                    }
                    Err((req, _reject)) => req,
                },
                Err(_) => req,
            };
            failed += 1;
            let _ = req.events.send(TokenEvent::Done(Response::failed(
                req.id,
                error.to_string(),
            )));
        }
        self.metrics.record_failover(rerouted as u64, failed as u64);
        (rerouted, failed)
    }

    /// Blocking pop from replica 0 — the legacy single-executor path
    /// (prefer [`Replica::pop_blocking`] via [`Router::replica`]).
    pub fn pop_blocking(&self) -> Option<Request> {
        self.replicas[0].pop_blocking()
    }

    /// Non-blocking drain of up to `n` requests from replica 0 (legacy
    /// single-executor path).
    pub fn pop_up_to(&self, n: usize) -> Vec<Request> {
        self.replicas[0].pop_up_to(n)
    }

    /// Total queued requests across all replicas.
    pub fn queue_depth(&self) -> usize {
        self.replicas.iter().map(|r| r.queue_len()).sum()
    }

    /// Stop accepting work and wake every executor so the pool drains.
    pub fn close(&self) {
        for r in &self.replicas {
            r.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn router(max_queue: usize) -> Router {
        Router::new(max_queue, 4096, 64, 128, Arc::new(Metrics::new()))
    }

    fn pooled(max_queue: usize, replicas: usize) -> Router {
        Router::new_pooled(
            max_queue,
            4096,
            256,
            128,
            Arc::new(Metrics::new()),
            replicas,
            LoadEstimator::new(128),
            0,
        )
    }

    fn batch_opts() -> SubmitOpts {
        SubmitOpts {
            class: SloClass::Batch,
            ..Default::default()
        }
    }

    #[test]
    fn admits_and_pops_fifo() {
        let r = router(4);
        let (tx, _rx) = channel();
        let id1 = r
            .submit(vec![1; 10], 4, SparsityConfig::dense(), tx.clone())
            .unwrap();
        let id2 = r
            .submit(vec![2; 10], 4, SparsityConfig::dense(), tx)
            .unwrap();
        assert!(id2 > id1);
        assert_eq!(r.queue_depth(), 2);
        assert_eq!(r.pop_blocking().unwrap().id, id1);
        assert_eq!(r.pop_up_to(5).len(), 1);
    }

    #[test]
    fn interactive_outranks_batch_in_pop_order() {
        let r = router(8);
        let (tx, _rx) = channel();
        let b1 = r
            .submit_with(vec![1; 8], 1, SparsityConfig::dense(),
                         batch_opts(), tx.clone())
            .unwrap();
        let i1 = r
            .submit(vec![2; 8], 1, SparsityConfig::dense(), tx.clone())
            .unwrap();
        let b2 = r
            .submit_with(vec![3; 8], 1, SparsityConfig::dense(),
                         batch_opts(), tx)
            .unwrap();
        // interactive pops first even though it arrived second
        assert_eq!(r.pop_blocking().unwrap().id, i1);
        assert_eq!(r.pop_blocking().unwrap().id, b1);
        assert_eq!(r.pop_blocking().unwrap().id, b2);
    }

    #[test]
    fn requeue_returns_to_front_of_class_queue() {
        let r = router(8);
        let (tx, _rx) = channel();
        r.submit_with(vec![1; 8], 1, SparsityConfig::dense(),
                      batch_opts(), tx.clone())
            .unwrap();
        r.submit_with(vec![2; 8], 1, SparsityConfig::dense(),
                      batch_opts(), tx)
            .unwrap();
        let rep = r.replica(0);
        let first = rep.pop_blocking().unwrap();
        let first_id = first.id;
        let load_before = rep.load();
        rep.requeue(first);
        // cost moved back queued; FIFO order preserved
        assert!((rep.load() - load_before).abs() < 1e-9);
        assert_eq!(rep.pop_blocking().unwrap().id, first_id);
    }

    #[test]
    fn rejects_on_queue_full() {
        let r = router(1);
        let (tx, _rx) = channel();
        r.submit(vec![1; 8], 1, SparsityConfig::dense(), tx.clone())
            .unwrap();
        let e = r
            .submit(vec![1; 8], 1, SparsityConfig::dense(), tx)
            .unwrap_err();
        assert_eq!(e, Reject::QueueFull);
    }

    #[test]
    fn rejects_long_prompts() {
        let r = router(4);
        let (tx, _rx) = channel();
        let e = r
            .submit(vec![0; 5000], 10, SparsityConfig::dense(), tx)
            .unwrap_err();
        assert!(matches!(e, Reject::PromptTooLong { .. }));
    }

    #[test]
    fn rejects_when_kv_exhausted() {
        // pool: 64 pages * 128 = 8192 positions; max_ctx 4096 passes the
        // length check; exhaust the pool first
        let r = router(4);
        {
            let mut pool = r.kv_pool.lock().unwrap();
            let _leak = pool.allocate(64).unwrap();
            std::mem::forget(_leak);
        }
        let (tx, _rx) = channel();
        let e = r
            .submit(vec![0; 1000], 10, SparsityConfig::dense(), tx)
            .unwrap_err();
        assert_eq!(e, Reject::KvExhausted);
    }

    #[test]
    fn close_unblocks_pop() {
        let r = Arc::new(router(2));
        let r2 = r.clone();
        let h = std::thread::spawn(move || r2.pop_blocking().is_none());
        std::thread::sleep(std::time::Duration::from_millis(20));
        r.close();
        assert!(h.join().unwrap());
    }

    #[test]
    fn cancel_token_is_shared() {
        let opts = SubmitOpts::default();
        let token = opts.cancel.clone();
        assert!(!token.is_cancelled());
        let r = router(4);
        let (tx, _rx) = channel();
        r.submit_with(vec![1; 8], 1, SparsityConfig::dense(), opts, tx)
            .unwrap();
        let req = r.pop_blocking().unwrap();
        token.cancel();
        assert!(req.cancel.is_cancelled(), "cancellation reaches executor");
    }

    #[test]
    fn estimator_units() {
        let e = LoadEstimator::new(128);
        // 2 full blocks + 5 tail tokens + 4 decode steps at unit weight
        assert!((e.cost(261, 4) - 11.0).abs() < 1e-12);
        assert_eq!(e.cost(0, 0), 0.0);
        let fm = LoadEstimator::from_cost_model(
            &crate::cost::CostModel::llama8b(),
        );
        assert!(fm.decode_unit > 0.0 && fm.decode_unit < 0.1,
                "flop-weighted decode unit should be ~1/block: {}",
                fm.decode_unit);
        // tail tokens are T=1 steps: under FLOP weighting a 1023-token
        // prompt must cost about the same as a 1024-token one, not 17x
        let near = fm.cost(1023, 0);
        let aligned = fm.cost(1024, 0);
        assert!(
            near < aligned * 1.1 && near > aligned * 0.5,
            "unaligned prompt over-costed: {near} vs {aligned}"
        );
    }

    #[test]
    fn dispatch_is_least_loaded() {
        let r = pooled(16, 2);
        let (tx, _rx) = channel();
        // heavy request lands on replica 0 (both idle, lowest id wins)
        r.submit(vec![1; 512], 0, SparsityConfig::dense(), tx.clone())
            .unwrap();
        assert_eq!(r.replica(0).queue_len(), 1);
        // the next two light requests both prefer replica 1 (4 blocks of
        // queued load on replica 0 vs 1-2 on replica 1)
        r.submit(vec![2; 128], 0, SparsityConfig::dense(), tx.clone())
            .unwrap();
        r.submit(vec![3; 128], 0, SparsityConfig::dense(), tx)
            .unwrap();
        assert_eq!(r.replica(0).queue_len(), 1);
        assert_eq!(r.replica(1).queue_len(), 2);
    }

    #[test]
    fn inflight_load_counts_until_complete() {
        let r = pooled(16, 2);
        let (tx, _rx) = channel();
        r.submit(vec![1; 256], 8, SparsityConfig::dense(), tx.clone())
            .unwrap();
        let rep = r.replica(0);
        let queued = rep.load();
        assert!(queued > 0.0);
        let req = rep.pop_blocking().unwrap();
        // popped but not complete: load unchanged (moved to in-flight)
        assert!((rep.load() - queued).abs() < 1e-9);
        rep.complete(req.prompt.len(), req.max_tokens);
        assert_eq!(rep.load(), 0.0);
    }

    #[test]
    fn admission_reclaims_prefix_pages() {
        use crate::kvcache::SeqKvCache;
        let r = Router::new_pooled(
            8,
            4096,
            8, // 8 pages total
            128,
            Arc::new(Metrics::new()),
            1,
            LoadEstimator::new(128),
            1 << 30,
        );
        // fill the entire pool with cached prefix blocks
        {
            let mut pc = r.prefix_cache.lock().unwrap();
            let mut pool = r.kv_pool.lock().unwrap();
            let toks: Vec<i32> = (0..1024).collect();
            let mut src = SeqKvCache::new(1, 1, 1, 1024);
            let zeros = vec![0.0; 128];
            for _ in 0..8 {
                src.append_layer(0, &zeros, &zeros, 128).unwrap();
                src.advance(128);
            }
            assert_eq!(pc.insert(1, &toks, usize::MAX, &src, &mut pool), 8);
            assert_eq!(pool.free_pages(), 0);
        }
        // a live request must still admit: unpinned cached residency is
        // reclaimed instead of rejecting with KvExhausted forever
        let (tx, _rx) = channel();
        r.submit(vec![7; 200], 10, SparsityConfig::dense(), tx)
            .unwrap();
        assert_eq!(r.prefix_cache.lock().unwrap().entry_count(), 6);
        assert!(r.kv_pool.lock().unwrap().free_pages() >= 2);
    }

    #[test]
    fn dead_replicas_are_skipped_and_drained() {
        let r = pooled(16, 2);
        let (tx, rx) = channel();
        r.submit(vec![1; 64], 2, SparsityConfig::dense(), tx.clone())
            .unwrap();
        assert_eq!(r.replica(0).queue_len(), 1);
        r.replica(0).mark_dead("engine failed to load");
        // the queued request got a terminal error event
        let resp = Response::collect(&rx).expect("Done event");
        assert!(resp.error.unwrap().contains("failed to load"));
        // new work routes around the dead replica
        r.submit(vec![2; 64], 2, SparsityConfig::dense(), tx.clone())
            .unwrap();
        assert_eq!(r.replica(1).queue_len(), 1);
        // with every replica dead, admission rejects
        r.replica(1).mark_dead("gone");
        let e = r
            .submit(vec![3; 64], 2, SparsityConfig::dense(), tx)
            .unwrap_err();
        assert_eq!(e, Reject::Unavailable);
    }

    /// Executor death under a live burst: replica 0 dies with queued
    /// work while new submissions race the failover. Everything the
    /// router *accepted* must still get exactly one `Done` — re-routed
    /// to the survivor, never lost, never spuriously errored. (A submit
    /// refused inside the mark-dead window is fine: that client was
    /// told synchronously.)
    #[test]
    fn failover_under_churn_loses_no_responses() {
        let r = Arc::new(pooled(256, 2));

        // seed a burst before any consumer runs, so both replicas hold
        // queued work deterministically (least-loaded alternates)
        let mut rxs = Vec::new();
        for i in 0..16usize {
            let (tx, rx) = channel();
            r.submit(vec![(i % 250) as i32 + 1; 64], 2,
                     SparsityConfig::dense(), tx)
                .unwrap();
            rxs.push(rx);
        }
        assert!(r.replica(0).queue_len() > 0, "burst missed replica 0");
        assert!(r.replica(1).queue_len() > 0, "burst missed replica 1");

        // consumer services replica 1 only: replica 0's executor has
        // "crashed" mid-burst with its queue intact
        let consumer = {
            let r = r.clone();
            std::thread::spawn(move || {
                let mut served = 0usize;
                while let Some(req) = r.replica(1).pop_blocking() {
                    r.replica(1)
                        .complete(req.prompt.len(), req.max_tokens);
                    let _ = req.events.send(TokenEvent::Done(Response {
                        id: req.id,
                        text: String::new(),
                        tokens: 1,
                        ttft_ms: 0.1,
                        tpot_ms: 0.1,
                        e2e_ms: 0.2,
                        reused_blocks: 0,
                        error: None,
                    }));
                    served += 1;
                }
                served
            })
        };

        // churn: 16 more submissions race the fail_over call below
        let churn = {
            let r = r.clone();
            std::thread::spawn(move || {
                let mut accepted = Vec::new();
                for i in 0..16usize {
                    let (tx, rx) = channel();
                    if r.submit(vec![(i % 250) as i32 + 1; 64], 2,
                                SparsityConfig::dense(), tx)
                        .is_ok()
                    {
                        accepted.push(rx);
                    }
                }
                accepted
            })
        };

        let (rerouted, failed) = r.fail_over(0, "replica 0 died");
        assert!(rerouted > 0,
                "replica 0's queue must re-route, not vanish");
        assert_eq!(failed, 0,
                   "survivor had queue room — nothing may fail");

        rxs.extend(churn.join().unwrap());
        for rx in &rxs {
            let resp = Response::collect(rx).expect("lost Done event");
            assert!(resp.error.is_none(),
                    "re-routed request errored: {:?}", resp.error);
        }

        r.close();
        let served = consumer.join().unwrap();
        assert_eq!(served, rxs.len(),
                   "every accepted request flows through the survivor");
    }
}
