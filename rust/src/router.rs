//! Request router: admission control, bounded queueing, backpressure.
//!
//! The router sits between the (multi-threaded) HTTP front-end and the
//! single-threaded engine executor. Admission enforces (a) a queue-depth
//! bound and (b) KV-memory feasibility via the paged allocator, rejecting
//! early (HTTP 429) rather than letting latency collapse.

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};

use crate::engine::SparsityConfig;
use crate::kvcache::PagedAllocator;
use crate::metrics::Metrics;

/// A queued generation request.
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_tokens: usize,
    pub cfg: SparsityConfig,
    /// Channel the finished response is delivered on.
    pub respond: Sender<Response>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub tokens: usize,
    pub ttft_ms: f64,
    pub tpot_ms: f64,
    pub e2e_ms: f64,
    pub error: Option<String>,
}

/// Rejection reasons surfaced to clients.
#[derive(Debug, Clone, PartialEq)]
pub enum Reject {
    QueueFull,
    PromptTooLong { len: usize, max: usize },
    KvExhausted,
}

struct Inner {
    queue: VecDeque<Request>,
    next_id: u64,
    closed: bool,
}

/// Thread-safe router handle.
pub struct Router {
    inner: Mutex<Inner>,
    notify: Condvar,
    pub max_queue: usize,
    pub max_ctx: usize,
    pub kv_pool: Mutex<PagedAllocator>,
    pub metrics: Arc<Metrics>,
}

impl Router {
    pub fn new(max_queue: usize, max_ctx: usize, kv_pages: usize,
               page_size: usize, metrics: Arc<Metrics>) -> Self {
        Router {
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                next_id: 1,
                closed: false,
            }),
            notify: Condvar::new(),
            max_queue,
            max_ctx,
            kv_pool: Mutex::new(PagedAllocator::new(kv_pages, page_size)),
            metrics,
        }
    }

    /// Admit a request or reject with a reason.
    pub fn submit(&self, prompt: Vec<i32>, max_tokens: usize,
                  cfg: SparsityConfig, respond: Sender<Response>)
                  -> Result<u64, Reject> {
        let total = prompt.len() + max_tokens;
        if total > self.max_ctx {
            self.metrics.record_rejection();
            return Err(Reject::PromptTooLong {
                len: total,
                max: self.max_ctx,
            });
        }
        {
            let pool = self.kv_pool.lock().unwrap();
            if !pool.can_allocate(total) {
                self.metrics.record_rejection();
                return Err(Reject::KvExhausted);
            }
        }
        let mut g = self.inner.lock().unwrap();
        if g.queue.len() >= self.max_queue {
            self.metrics.record_rejection();
            return Err(Reject::QueueFull);
        }
        let id = g.next_id;
        g.next_id += 1;
        g.queue.push_back(Request {
            id,
            prompt,
            max_tokens,
            cfg,
            respond,
        });
        drop(g);
        self.notify.notify_one();
        Ok(id)
    }

    /// Blocking pop for the executor thread; None once closed and empty.
    pub fn pop_blocking(&self) -> Option<Request> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.queue.pop_front() {
                return Some(r);
            }
            if g.closed {
                return None;
            }
            g = self.notify.wait(g).unwrap();
        }
    }

    /// Non-blocking drain of up to `n` requests (batcher admission).
    pub fn pop_up_to(&self, n: usize) -> Vec<Request> {
        let mut g = self.inner.lock().unwrap();
        let take = n.min(g.queue.len());
        g.queue.drain(..take).collect()
    }

    pub fn queue_depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.notify.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn router(max_queue: usize) -> Router {
        Router::new(max_queue, 4096, 64, 128, Arc::new(Metrics::new()))
    }

    #[test]
    fn admits_and_pops_fifo() {
        let r = router(4);
        let (tx, _rx) = channel();
        let id1 = r
            .submit(vec![1; 10], 4, SparsityConfig::dense(), tx.clone())
            .unwrap();
        let id2 = r
            .submit(vec![2; 10], 4, SparsityConfig::dense(), tx)
            .unwrap();
        assert!(id2 > id1);
        assert_eq!(r.queue_depth(), 2);
        assert_eq!(r.pop_blocking().unwrap().id, id1);
        assert_eq!(r.pop_up_to(5).len(), 1);
    }

    #[test]
    fn rejects_on_queue_full() {
        let r = router(1);
        let (tx, _rx) = channel();
        r.submit(vec![1; 8], 1, SparsityConfig::dense(), tx.clone())
            .unwrap();
        let e = r
            .submit(vec![1; 8], 1, SparsityConfig::dense(), tx)
            .unwrap_err();
        assert_eq!(e, Reject::QueueFull);
    }

    #[test]
    fn rejects_long_prompts() {
        let r = router(4);
        let (tx, _rx) = channel();
        let e = r
            .submit(vec![0; 5000], 10, SparsityConfig::dense(), tx)
            .unwrap_err();
        assert!(matches!(e, Reject::PromptTooLong { .. }));
    }

    #[test]
    fn rejects_when_kv_exhausted() {
        // pool: 64 pages * 128 = 8192 positions; max_ctx 4096 passes the
        // length check; exhaust the pool first
        let r = router(4);
        {
            let mut pool = r.kv_pool.lock().unwrap();
            let _leak = pool.allocate(64).unwrap();
            std::mem::forget(_leak);
        }
        let (tx, _rx) = channel();
        let e = r
            .submit(vec![0; 1000], 10, SparsityConfig::dense(), tx)
            .unwrap_err();
        assert_eq!(e, Reject::KvExhausted);
    }

    #[test]
    fn close_unblocks_pop() {
        let r = Arc::new(router(2));
        let r2 = r.clone();
        let h = std::thread::spawn(move || r2.pop_blocking().is_none());
        std::thread::sleep(std::time::Duration::from_millis(20));
        r.close();
        assert!(h.join().unwrap());
    }
}
