//! Dependency-free worker-thread pool for the CPU backend's data
//! parallelism (std-only substrate; the vendored crate set has no rayon
//! or crossbeam).
//!
//! The pool executes *index-parallel* jobs: [`ThreadPool::run`] takes a
//! task count `n` and a closure `f`, and guarantees `f(i)` is called
//! exactly once for every `i in 0..n` before `run` returns. The calling
//! thread participates in the work (a pool of `threads == N` means `N`
//! lanes total: the caller plus `N - 1` workers), so `threads == 1`
//! degenerates to a plain inline loop with zero synchronization.
//!
//! Determinism contract: the pool only decides *which lane* executes a
//! task index, never the work done for it. Callers partition output
//! elements so each element is computed by exactly one task with a
//! fixed sequential accumulation order — which is what makes the fast
//! CPU backend bit-identical across `threads ∈ {1, 4, …}` and against
//! the sequential reference (see `runtime/cpu.rs` and
//! `tests/backend_conformance.rs`).
//!
//! Thread-count resolution (see [`resolve_threads`]): explicit request
//! (`--cpu-threads`) → `FF_CPU_THREADS` env var → available
//! parallelism, capped at [`MAX_AUTO_THREADS`].

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Environment variable overriding the default worker count.
pub const THREADS_ENV: &str = "FF_CPU_THREADS";

/// Cap applied when the thread count is *derived* from the machine's
/// available parallelism (explicit requests are honored as-is): beyond
/// this, the small GEMMs of the reference models stop scaling and pool
/// replicas multiply thread counts.
pub const MAX_AUTO_THREADS: usize = 8;

/// Resolve the lane count for a new pool: `explicit` (when `Some` and
/// non-zero) → `FF_CPU_THREADS` (when set, parseable and non-zero) →
/// `std::thread::available_parallelism()` capped at
/// [`MAX_AUTO_THREADS`].
pub fn resolve_threads(explicit: Option<usize>) -> usize {
    if let Some(n) = explicit {
        if n > 0 {
            return n;
        }
    }
    if let Ok(v) = std::env::var(THREADS_ENV) {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, MAX_AUTO_THREADS)
}

/// Type-erased pointer to the job closure of the batch in flight.
///
/// Stored as a raw pointer (not a reference) because worker threads may
/// still *hold* a `Task` handle briefly after [`ThreadPool::run`]
/// returns; they never dereference it once every index is claimed —
/// see the safety argument on [`ThreadPool::run`].
type RawJob = *const (dyn Fn(usize) + Sync + 'static);

/// One batch of `total` task indices being drained by the lanes.
struct Task {
    job: RawJob,
    total: usize,
    /// Next index to claim (fetch_add dispenser).
    cursor: AtomicUsize,
    /// Indices fully executed so far; completion == `total`.
    done: AtomicUsize,
    /// Set when any task index panicked (re-raised by the caller).
    panicked: AtomicBool,
    /// Mutex + condvar the caller blocks on until `done == total`.
    done_mx: Mutex<()>,
    done_cv: Condvar,
}

// SAFETY: `Task` is shared across threads only between the moment
// `ThreadPool::run` publishes it and the moment `run` observes
// `done == total`; within that window the closure behind `job` is alive
// (it is a stack borrow of `run`'s argument) and `Fn + Sync`, so calling
// it concurrently is sound. After the window the pointer may dangle but
// is never dereferenced (the cursor is exhausted).
unsafe impl Send for Task {}
unsafe impl Sync for Task {}

impl Task {
    /// Claim and execute indices until the cursor is exhausted.
    fn work(&self) {
        loop {
            let i = self.cursor.fetch_add(1, Ordering::Relaxed);
            if i >= self.total {
                break;
            }
            // SAFETY: `i < total`, so the batch is still in its live
            // window (the caller cannot have returned: it waits for
            // `done == total` and we have not counted `i` yet).
            let job = unsafe { &*self.job };
            if catch_unwind(AssertUnwindSafe(|| job(i))).is_err() {
                self.panicked.store(true, Ordering::Release);
            }
            let d = self.done.fetch_add(1, Ordering::AcqRel) + 1;
            if d == self.total {
                // Take the lock before notifying so the caller can't
                // miss the wakeup between its check and its wait.
                let _g = self.done_mx.lock().unwrap();
                self.done_cv.notify_all();
            }
        }
    }
}

/// Shared worker state: a single-slot inbox of the batch in flight.
struct Shared {
    inbox: Mutex<Inbox>,
    work_cv: Condvar,
}

struct Inbox {
    /// Batch workers should help drain, if any.
    task: Option<Arc<Task>>,
    shutdown: bool,
}

/// A fixed-size worker pool executing index-parallel jobs. See the
/// module docs for the determinism contract.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Pool with `threads` lanes (min 1). The caller is one lane, so
    /// `threads - 1` OS threads are spawned; `new(1)` spawns none and
    /// [`ThreadPool::run`] becomes an inline loop.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            inbox: Mutex::new(Inbox {
                task: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("ff-cpu-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn cpu pool worker")
            })
            .collect();
        ThreadPool {
            shared,
            workers,
            threads,
        }
    }

    /// Pool sized by [`resolve_threads`] (no explicit request).
    pub fn from_env() -> ThreadPool {
        ThreadPool::new(resolve_threads(None))
    }

    /// Total lanes (caller + workers).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute `f(i)` exactly once for every `i in 0..tasks`, using all
    /// lanes, returning when every index has completed. Panics (after
    /// all indices finish) if any index panicked.
    ///
    /// The closure only needs to borrow its environment for the
    /// duration of the call: internally it is published to the workers
    /// through a raw pointer, which is sound because this method does
    /// not return until every index has executed (`done == total`) and
    /// no worker dereferences the pointer after the cursor is
    /// exhausted.
    pub fn run<F>(&self, tasks: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if tasks == 0 {
            return;
        }
        if self.workers.is_empty() || tasks == 1 {
            for i in 0..tasks {
                f(i);
            }
            return;
        }
        let job_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: erase the stack lifetime; validity is guaranteed by
        // the completion barrier below (see method docs). A transmute
        // (not an `as` cast) because the trait-object *lifetime bound*
        // changes, which pointer casts cannot express on all toolchains.
        #[allow(clippy::useless_transmute,
                clippy::transmutes_expressible_as_ptr_casts)]
        let job: RawJob = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), RawJob>(job_ref)
        };
        let task = Arc::new(Task {
            job,
            total: tasks,
            cursor: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            done_mx: Mutex::new(()),
            done_cv: Condvar::new(),
        });
        {
            let mut inbox = self.shared.inbox.lock().unwrap();
            // Single-slot inbox: if a batch is already in flight (a
            // nested `run` from inside a job), drain inline instead —
            // correctness never depends on extra lanes.
            if inbox.task.is_some() {
                drop(inbox);
                for i in 0..tasks {
                    f(i);
                }
                return;
            }
            inbox.task = Some(task.clone());
        }
        self.shared.work_cv.notify_all();
        // The caller is a lane too: claim indices until exhausted.
        task.work();
        // Completion barrier: wait until every claimed index finished.
        {
            let mut g = task.done_mx.lock().unwrap();
            while task.done.load(Ordering::Acquire) < task.total {
                g = task.done_cv.wait(g).unwrap();
            }
        }
        // Retire the batch so the next `run` can publish.
        {
            let mut inbox = self.shared.inbox.lock().unwrap();
            inbox.task = None;
        }
        if task.panicked.load(Ordering::Acquire) {
            panic!("cpu thread pool: a parallel task panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut inbox = self.shared.inbox.lock().unwrap();
            inbox.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let task = {
            let mut inbox = shared.inbox.lock().unwrap();
            loop {
                if let Some(t) = inbox.task.clone() {
                    // Leave the slot occupied: the publishing `run`
                    // retires it after completion. Exhausted batches
                    // (cursor >= total) are no-ops in `work`.
                    if t.cursor.load(Ordering::Relaxed) < t.total {
                        break Some(t);
                    }
                }
                if inbox.shutdown {
                    break None;
                }
                inbox = shared.work_cv.wait(inbox).unwrap();
            }
        };
        match task {
            Some(t) => t.work(),
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_index_exactly_once() {
        for threads in [1, 2, 4] {
            let pool = ThreadPool::new(threads);
            let n = 257;
            let hits: Vec<AtomicUsize> =
                (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "threads={threads}: some index not run exactly once"
            );
        }
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let pool = ThreadPool::new(4);
        let n = 1000usize;
        let total = AtomicU64::new(0);
        pool.run(n, |i| {
            total.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(
            total.load(Ordering::Relaxed),
            (n as u64 - 1) * n as u64 / 2
        );
    }

    #[test]
    fn zero_tasks_is_a_noop() {
        let pool = ThreadPool::new(3);
        pool.run(0, |_| panic!("must not run"));
    }

    #[test]
    fn nested_run_does_not_deadlock() {
        let pool = ThreadPool::new(4);
        let total = AtomicU64::new(0);
        pool.run(8, |_| {
            // nested batch: drained inline by the single-slot rule
            pool.run(8, |j| {
                total.fetch_add(j as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 8 * 28);
    }

    #[test]
    #[should_panic(expected = "parallel task panicked")]
    fn task_panic_propagates_to_caller() {
        let pool = ThreadPool::new(2);
        pool.run(16, |i| {
            if i == 7 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn pool_survives_a_panicked_batch() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, |i| {
                if i == 0 {
                    panic!("first batch dies");
                }
            });
        }));
        assert!(r.is_err());
        let total = AtomicU64::new(0);
        pool.run(4, |i| {
            total.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn resolve_threads_precedence() {
        // explicit wins regardless of env
        assert_eq!(resolve_threads(Some(3)), 3);
        // zero is "unset"
        assert!(resolve_threads(Some(0)) >= 1);
        assert!(resolve_threads(None) >= 1);
        assert!(resolve_threads(None) <= MAX_AUTO_THREADS.max(1));
    }
}
