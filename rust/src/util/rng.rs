//! Deterministic PRNG + distributions (std-only substrate; the vendored
//! crate set has no `rand`). SplitMix64 core — statistically solid for
//! workload generation and property tests, and reproducible across runs.

/// SplitMix64 deterministic PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded generator (same seed → same stream).
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std, truncated at lo.
    pub fn normal_trunc(&mut self, mean: f64, std: f64, lo: f64) -> f64 {
        for _ in 0..64 {
            let x = mean + std * self.normal();
            if x >= lo {
                return x;
            }
        }
        lo
    }

    /// Zipf over [0, n) with exponent s (rejection-free inverse-CDF over a
    /// precomputed table would be faster; n here is small).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        let h: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let mut u = self.f64() * h;
        for k in 1..=n {
            u -= (k as f64).powf(-s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Random permutation prefix: k distinct indices from [0, n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.range(0, i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.zipf(10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[4]);
        assert!(counts[4] > counts[9].saturating_sub(50));
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(4);
        let got = r.choose_k(100, 30);
        let mut sorted = got.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let x = r.range(3, 17);
            assert!((3..17).contains(&x));
        }
    }
}
