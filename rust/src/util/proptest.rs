//! Property-testing harness (std-only substrate for the absent proptest
//! crate): runs a property over many seeded random cases and, on failure,
//! reports the seed so the case can be replayed deterministically.

use super::rng::Rng;

/// Run `prop` over `cases` random cases. `prop` receives a fresh Rng per
/// case and returns Err(description) on violation. Panics with the seed
/// of the first failing case.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let base = 0xFA57F0A4u64;
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed (case {i}, seed {seed:#x}): {msg}");
        }
    }
}

/// Assert-like helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("add-commutes", 100, |r| {
            let a = r.range(0, 1000) as i64;
            let b = r.range(0, 1000) as i64;
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failures() {
        check("always-fails", 10, |_| Err("nope".into()));
    }
}
