//! Property-testing harness (std-only substrate for the absent proptest
//! crate): runs a property over many seeded random cases and, on failure,
//! reports the seed so the case can be replayed deterministically —
//! `FF_TEST_SEED=<reported seed> cargo test <test>` reruns exactly the
//! failing case (`crate::testing::TEST_SEED_ENV`).

use super::rng::Rng;

/// Run `prop` over `cases` random cases. `prop` receives a fresh Rng per
/// case and returns Err(description) on violation. Panics with the seed
/// of the first failing case, in the exact spelling `FF_TEST_SEED`
/// accepts. When `FF_TEST_SEED` is set, only that seed runs — a
/// deterministic replay of a reported failure, regardless of which
/// case index originally produced it.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    if let Some(seed) = crate::testing::seed_override() {
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed (replay, seed {seed:#x}): {msg}"
            );
        }
        return;
    }
    let base = 0xFA57F0A4u64;
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed (case {i}, seed {seed:#x}): \
                 {msg} — replay with FF_TEST_SEED={seed:#x}"
            );
        }
    }
}

/// Assert-like helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("add-commutes", 100, |r| {
            let a = r.range(0, 1000) as i64;
            let b = r.range(0, 1000) as i64;
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failures() {
        check("always-fails", 10, |_| Err("nope".into()));
    }

    #[test]
    #[should_panic(expected = "replay with FF_TEST_SEED=")]
    fn failure_message_advises_seed_replay() {
        // only meaningful when no replay override is active — under an
        // override the replay panic message is the expected one anyway
        if std::env::var(crate::testing::TEST_SEED_ENV).is_ok() {
            panic!("replay with FF_TEST_SEED= (override active)");
        }
        check("always-fails", 1, |_| Err("nope".into()));
    }
}
