//! Tiny CLI argument parser (std-only substrate; no clap in the vendored
//! crate set). Supports `--flag`, `--key value`, `--key=value` and
//! positional arguments, with typed accessors and defaults.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    /// Non-flag arguments in order (subcommand first).
    pub positional: Vec<String>,
}

/// Sentinel value stored for bare `--flag` switches.
pub const FLAG_SET: &str = "__set__";

impl Args {
    /// Parse the process arguments (skipping argv[0]).
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse an explicit argument iterator.
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.flags.insert(rest.to_string(), v);
                } else {
                    args.flags.insert(rest.to_string(), FLAG_SET.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Whether `--key` was passed (with or without a value).
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// String flag with default.
    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// String flag, None when absent.
    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    /// usize flag with default (default also on parse failure).
    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// f64 flag with default (default also on parse failure).
    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Comma-separated list of f64 ("0.3,0.4,0.5").
    pub fn f64_list(&self, key: &str, default: &[f64]) -> Vec<f64> {
        match self.flags.get(key) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|p| !p.is_empty())
                .filter_map(|p| p.trim().parse().ok())
                .collect(),
        }
    }

    /// Comma-separated list of usize ("256,512,1024").
    pub fn usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.flags.get(key) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|p| !p.is_empty())
                .filter_map(|p| p.trim().parse().ok())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_kinds() {
        let a = args("serve --port 8080 --verbose --mode=sparse pos1");
        assert_eq!(a.positional, vec!["serve", "pos1"]);
        assert_eq!(a.usize("port", 0), 8080);
        assert!(a.has("verbose"));
        assert_eq!(a.str("mode", "dense"), "sparse");
        assert_eq!(a.str("missing", "dflt"), "dflt");
    }

    #[test]
    fn lists() {
        let a = args("--sparsity 0.3,0.4,0.5 --ctx 512,1024");
        assert_eq!(a.f64_list("sparsity", &[]), vec![0.3, 0.4, 0.5]);
        assert_eq!(a.usize_list("ctx", &[]), vec![512, 1024]);
        assert_eq!(a.usize_list("other", &[7]), vec![7]);
    }

    #[test]
    fn flag_then_flag() {
        let a = args("--a --b v");
        assert!(a.has("a"));
        assert_eq!(a.str("b", ""), "v");
    }
}
