//! Minimal JSON parser/serializer (std-only substrate).
//!
//! The offline build environment vendors no serde, so the manifest,
//! schedule and HTTP payloads are handled by this module. It supports the
//! full JSON grammar minus exotic escapes (\u surrogate pairs are decoded
//! to the replacement char). Numbers parse as f64; integer accessors
//! round-trip exactly for |n| < 2^53, which covers every offset/shape in
//! the artifact manifest.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (f64; integers exact below 2^53).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----- accessors -----------------------------------------------------
    /// Object member lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object member or error (for required manifest fields).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key: {key}"))
    }

    /// Number as f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Number truncated to i64.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    /// Non-negative integral number as usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    /// String contents.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array elements.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object members.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: array of usize (shapes etc).
    pub fn usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("expected usize"))
            })
            .collect()
    }

    /// Convenience: array of f64 (schedules etc).
    pub fn f64_vec(&self) -> anyhow::Result<Vec<f64>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("expected num")))
            .collect()
    }

    // ----- construction helpers ------------------------------------------
    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a string array.
    pub fn from_str_slice(items: &[&str]) -> Json {
        Json::Arr(items.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ----- serialization --------------------------------------------------
    /// Serialize to compact JSON text.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Parse a complete JSON document (rejects trailing garbage).
pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected {:?} at byte {} found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other, self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.pos += 4;
                        }
                        other => anyhow::bail!("bad escape {:?}", other),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                other => anyhow::bail!("bad array sep {:?}", other),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                other => anyhow::bail!("bad object sep {:?}", other),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",true,null],"n":{"x":-3}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn usize_vec() {
        let v = parse("[128, 4, 32]").unwrap();
        assert_eq!(v.usize_vec().unwrap(), vec![128, 4, 32]);
    }

    #[test]
    fn integers_roundtrip_exact() {
        let v = parse("9007199254740992").unwrap(); // 2^53
        assert_eq!(v.as_i64(), Some(9007199254740992));
    }
}
