//! FNV-1a hashing substrate shared by the numeric-identity
//! fingerprints (weight values, model identity, runtime fingerprint —
//! the inputs to [`crate::engine::Engine::prefix_seed`]). One
//! implementation, one finalization, so the prefix-cache safety chain
//! stays auditable. Process-local only: these values are never
//! persisted, so the scheme may evolve freely.

/// FNV-1a offset basis — the initial state for [`mix`] chains.
pub const BASIS: u64 = 0xcbf29ce484222325;

/// FNV-1a prime.
const PRIME: u64 = 0x100000001b3;

/// FNV-1a over a byte string.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    bytes
        .iter()
        .fold(BASIS, |h, &b| (h ^ b as u64).wrapping_mul(PRIME))
}

/// Fold one 64-bit value into a running hash: FNV-style multiply plus
/// an avalanche shift so small integer inputs still diffuse.
pub fn mix(h: u64, v: u64) -> u64 {
    let x = (h ^ v).wrapping_mul(PRIME);
    x ^ (x >> 29)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_distinguishes_and_is_stable() {
        assert_eq!(fnv1a(b"abc"), fnv1a(b"abc"));
        assert_ne!(fnv1a(b"abc"), fnv1a(b"abd"));
        assert_ne!(fnv1a(b""), 0);
    }

    #[test]
    fn mix_is_order_sensitive() {
        let a = mix(mix(BASIS, 1), 2);
        let b = mix(mix(BASIS, 2), 1);
        assert_ne!(a, b);
        assert_ne!(mix(BASIS, 0), BASIS);
    }
}
