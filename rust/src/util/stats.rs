//! Summary statistics + timing helpers for benches and metrics.

use std::time::{Duration, Instant};

/// Accumulates samples; computes mean, std, percentiles.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one sample.
    pub fn add(&mut self, v: f64) {
        self.samples.push(v);
    }

    /// Sample count.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// Whether no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation (0 below two samples).
    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self
            .samples
            .iter()
            .map(|x| (x - m) * (x - m))
            .sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }

    /// Percentile by linear interpolation, q in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        // Total order: a NaN sample must not panic the metrics thread.
        s.sort_by(|a, b| a.total_cmp(b));
        let pos = q / 100.0 * (s.len() - 1) as f64;
        // f64 round-off can push `pos` a hair past len-1 (e.g. q=100
        // with len where (len-1)·100/100 lands above the integer), so
        // both indices are clamped back in range instead of trusting
        // floor/ceil to stay there.
        let last = s.len() - 1;
        let lo = (pos.floor() as usize).min(last);
        let hi = (pos.ceil() as usize).min(last);
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
        }
    }

    /// Smallest sample (+inf when empty).
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample (-inf when empty).
    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Bench harness (criterion substitute): warmup + timed reps with
/// mean ± std reporting, returning the per-iteration mean in seconds.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        s.add(t0.elapsed().as_secs_f64());
    }
    println!(
        "{name:48} {:>10.3} ms ± {:>7.3} (n={reps}, p50={:.3} p95={:.3})",
        s.mean() * 1e3,
        s.std() * 1e3,
        s.percentile(50.0) * 1e3,
        s.percentile(95.0) * 1e3,
    );
    s.mean()
}

/// Measure one closure, returning (result, elapsed).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(v);
        }
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.percentile(50.0) - 3.0).abs() < 1e-12);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 5.0).abs() < 1e-12);
        assert!((s.std() - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_summary_is_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
    }

    /// Regression: `pos.ceil() as usize` could land on `len` when the
    /// `q/100·(len-1)` product rounds a hair high, indexing one past
    /// the end. p100 and the tiny-sample shapes are the risk surface.
    #[test]
    fn percentile_edges_never_index_out_of_bounds() {
        let mut one = Summary::new();
        one.add(7.0);
        for q in [0.0, 33.3, 50.0, 99.999, 100.0] {
            assert_eq!(one.percentile(q), 7.0, "len=1 q={q}");
        }
        let mut two = Summary::new();
        two.add(1.0);
        two.add(3.0);
        assert_eq!(two.percentile(100.0), 3.0);
        assert_eq!(two.percentile(0.0), 1.0);
        assert!((two.percentile(50.0) - 2.0).abs() < 1e-12);
        // sweep q densely over an awkward length so any rounding that
        // escapes [0, len-1] panics here rather than in a bench
        let mut s = Summary::new();
        for i in 0..7 {
            s.add(i as f64);
        }
        let mut q = 0.0;
        while q <= 100.0 {
            let v = s.percentile(q);
            assert!((0.0..=6.0).contains(&v), "q={q} -> {v}");
            q += 0.1;
        }
        assert_eq!(s.percentile(100.0), 6.0);
    }
}
