//! Std-only substrates for the offline build environment (no serde /
//! clap / rand / criterion / proptest in the vendored crate set).

pub mod cli;
pub mod hash;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod threadpool;
