//! Synchronization helpers for the serving hot path.

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock a shared-state mutex, recovering from poisoning.
///
/// A panic on one executor thread (e.g. a failing forward pass unwinding
/// mid-insert) poisons any mutex it held; a bare `lock().unwrap()` on the
/// next thread then turns one request's panic into a process-wide cascade
/// — every subsequent admission dies on the same `PoisonError`. The
/// serving state guarded this way ([`crate::kvcache::PagedAllocator`],
/// [`crate::kvcache::PrefixCache`]) is repaired by the cancel sweep and
/// page-release accounting rather than by the panicking critical section,
/// so the right recovery is to take the guard and keep serving.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn recovers_after_poisoning_panic() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let mut g = m2.lock().unwrap();
            *g = 8;
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "lock must actually be poisoned");
        // the state mutated before the panic is still visible — callers
        // rely on external repair (sweeps), not rollback
        assert_eq!(*lock_recover(&m), 8);
        *lock_recover(&m) = 9;
        assert_eq!(*lock_recover(&m), 9);
    }
}
