//! Real-TPU performance estimation for the L1 Pallas kernels
//! (DESIGN.md §8): interpret-mode CPU timing is NOT a TPU proxy, so TPU
//! viability is argued structurally — per-kernel VMEM footprint against
//! the 16 MiB budget, MXU utilization from tile shapes, and arithmetic
//! intensity against the HBM roofline.

/// TPU-v4-ish per-core VMEM budget in bytes.
pub const VMEM_BYTES: usize = 16 * 1024 * 1024;
/// MXU systolic array dimension.
pub const MXU_DIM: usize = 128;
/// TPU v4 per-chip dense bf16 peak, FLOP/s.
pub const PEAK_BF16_FLOPS: f64 = 137.5e12;
/// HBM bandwidth, bytes/s.
pub const HBM_BW: f64 = 1.2e12;

/// One kernel grid-step's VMEM + compute profile.
#[derive(Debug, Clone)]
pub struct KernelProfile {
    /// Kernel + tile-shape label.
    pub name: String,
    /// VMEM footprint of one grid step.
    pub vmem_bytes: usize,
    /// FLOPs per grid step.
    pub flops_per_step: f64,
    /// HBM bytes streamed per grid step.
    pub hbm_bytes_per_step: f64,
    /// Fraction of MXU lanes busy given the tile shapes (dims / 128,
    /// capped at 1, multiplied across both systolic dimensions).
    pub mxu_utilization: f64,
}

impl KernelProfile {
    /// Whether the step fits the per-core VMEM budget.
    pub fn fits_vmem(&self) -> bool {
        self.vmem_bytes <= VMEM_BYTES
    }

    /// Arithmetic intensity in FLOP/byte.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.flops_per_step / self.hbm_bytes_per_step.max(1.0)
    }

    /// Roofline-limited throughput in TFLOP/s (min of compute and
    /// memory bounds at this intensity).
    pub fn roofline_tflops(&self) -> f64 {
        let compute = PEAK_BF16_FLOPS * self.mxu_utilization;
        let memory = HBM_BW * self.arithmetic_intensity();
        compute.min(memory) / 1e12
    }

    /// Achievable fraction of the MXU-degraded peak.
    pub fn efficiency_ratio(&self) -> f64 {
        let peak = PEAK_BF16_FLOPS * self.mxu_utilization;
        (self.roofline_tflops() * 1e12) / peak
    }
}

fn util(dim: usize) -> f64 {
    (dim as f64 / MXU_DIM as f64).min(1.0)
}

/// FFN kernel grid step (python/compile/kernels/ffn.py): an [T, d] block
/// against a [d, ftile]+[d, ftile]+[ftile, d] weight slab, f32 staging.
pub fn ffn_step(t: usize, d: usize, ftile: usize) -> KernelProfile {
    let el = 4; // f32 in this build; bf16 halves it on real TPU
    let vmem = el * (t * d            // x tile
        + 2 * d * ftile               // gate + up slabs
        + ftile * d                   // down slab
        + t * ftile                   // h intermediate
        + t * d); // accumulator
    let flops = 2.0 * (t * d * ftile) as f64 * 3.0; // three matmuls
    let hbm = el as f64 * (3 * d * ftile) as f64;   // weight slabs stream
    KernelProfile {
        name: format!("ffn t{t} d{d} ftile{ftile}"),
        vmem_bytes: vmem,
        flops_per_step: flops,
        hbm_bytes_per_step: hbm,
        mxu_utilization: util(t) * util(d.min(ftile)),
    }
}

/// Flash block-attention grid step (kernels/attention.py): [T, dh]
/// queries for one head against a [STILE, dh] KV tile.
pub fn attn_step(t: usize, dh: usize, stile: usize) -> KernelProfile {
    let el = 4;
    let vmem = el * (t * dh          // q
        + 2 * stile * dh             // k + v tiles
        + t * stile                  // scores/probs
        + t * dh                     // acc
        + 2 * t); // m, l scratch
    let flops = 2.0 * (t * stile * dh) as f64 * 2.0; // qk^T + pv
    let hbm = el as f64 * (2 * stile * dh) as f64;
    KernelProfile {
        name: format!("attn t{t} dh{dh} stile{stile}"),
        vmem_bytes: vmem,
        flops_per_step: flops,
        hbm_bytes_per_step: hbm,
        mxu_utilization: util(t) * util(dh),
    }
}

/// Predictor grid step (kernels/predictor.py).
pub fn predictor_step(t: usize, d: usize, r: usize,
                      ftile: usize) -> KernelProfile {
    let el = 4;
    let vmem = el * (t * d + d + d * r + r * ftile + ftile + r);
    let flops = 2.0 * ((t * d) + (d * r) + (r * ftile)) as f64;
    let hbm = el as f64 * (d * r + r * ftile) as f64;
    KernelProfile {
        name: format!("predictor t{t} d{d} r{r}"),
        vmem_bytes: vmem,
        flops_per_step: flops,
        hbm_bytes_per_step: hbm,
        mxu_utilization: util(1) * util(r), // rank-r GEMV-ish: low, but tiny
    }
}

/// The full per-kernel report for a model shape (printed by
/// `fastforward tpu-estimate` and recorded in EXPERIMENTS.md §Perf).
pub fn report(d: usize, d_ffn: usize, dh: usize, pred_r: usize,
              ftile: usize) -> Vec<KernelProfile> {
    vec![
        ffn_step(128, d, ftile),
        ffn_step(128, d, 128),          // MXU-native tile for comparison
        attn_step(128, dh, 128),
        predictor_step(128, d, pred_r, ftile.min(d_ffn)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_kernels_fit_vmem_at_paper_scale() {
        // Llama-8B shape: d=4096, ftile=128 — the slab schedule must fit
        for p in report(4096, 14336, 128, 256, 128) {
            assert!(
                p.fits_vmem(),
                "{} exceeds VMEM: {} MiB",
                p.name,
                p.vmem_bytes / (1024 * 1024)
            );
        }
    }

    #[test]
    fn ffn_kernel_intensity_is_t_over_2() {
        // Weight slabs stream once per block: intensity = T/2 FLOP/byte
        // in f32 (64 at T=128) — just under the v4 knee (~115), so the
        // f32 build is HBM-bound at ~0.56 of MXU peak; bf16 staging (the
        // real-TPU configuration) doubles intensity to 128 and crosses
        // into compute-bound. The estimate must reflect both honestly.
        let p = ffn_step(128, 4096, 128);
        assert!((p.arithmetic_intensity() - 64.0).abs() < 1e-9);
        assert!(p.mxu_utilization >= 0.99);
        let eff_f32 = p.efficiency_ratio();
        assert!((0.4..0.7).contains(&eff_f32), "eff {eff_f32}");
        // bf16: same FLOPs, half the bytes
        let mut bf16 = p.clone();
        bf16.hbm_bytes_per_step /= 2.0;
        assert!(bf16.efficiency_ratio() > 0.9,
                "bf16 eff {}", bf16.efficiency_ratio());
    }

    #[test]
    fn small_model_tiles_underuse_mxu() {
        // the ff-mini-128 build (ftile=64) trades MXU width for K
        // granularity — the report must expose that honestly
        let small = ffn_step(128, 128, 64);
        let native = ffn_step(128, 128, 128);
        assert!(small.mxu_utilization < native.mxu_utilization);
    }

    #[test]
    fn attention_tile_fits_and_streams() {
        let p = attn_step(128, 128, 128);
        assert!(p.fits_vmem());
        assert!(p.vmem_bytes < 1024 * 1024, "attn tile should be small");
    }
}
