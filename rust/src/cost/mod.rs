//! Compute-bound FLOP cost model for blockwise prefill (paper §2.3).
//!
//! The paper's Figure 7 "compute-bound speedup" is FLOPs-derived; this
//! module reproduces it analytically for any model shape, sparsity
//! schedule and context length, and also powers Figure 1/2's component
//! breakdown. A roofline constant (FLOPs/s) calibrated from a measured
//! matmul turns FLOPs into projected wall-clock.

pub mod tpu;

use crate::manifest::ModelCfg;

/// FLOPs for one transformer layer processing a block of `t` tokens with
/// a KV cache of `s_ctx` attendable positions, decomposed by component.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LayerFlops {
    /// QKVO projections.
    pub attn_proj: f64,
    /// QK^T and AV (token mixing).
    pub attn_mix: f64,
    /// Gated FFN at the layer's density.
    pub ffn: f64,
    /// Expert predictor overhead.
    pub predictor: f64,
    /// Error compensator overhead.
    pub comp: f64,
}

impl LayerFlops {
    /// Sum of every component.
    pub fn total(&self) -> f64 {
        self.attn_proj + self.attn_mix + self.ffn + self.predictor + self.comp
    }
}

/// FLOPs of a whole prefill, decomposed per layer.
#[derive(Debug, Clone)]
pub struct BlockCost {
    /// Accumulated FLOPs per transformer layer.
    pub per_layer: Vec<LayerFlops>,
}

impl BlockCost {
    /// Total FLOPs across layers and components.
    pub fn total(&self) -> f64 {
        self.per_layer.iter().map(|l| l.total()).sum()
    }

    /// Attention FLOPs (projections + mixing).
    pub fn attn(&self) -> f64 {
        self.per_layer
            .iter()
            .map(|l| l.attn_proj + l.attn_mix)
            .sum()
    }

    /// FFN FLOPs.
    pub fn ffn(&self) -> f64 {
        self.per_layer.iter().map(|l| l.ffn).sum()
    }

    /// Predictor + compensator overhead FLOPs.
    pub fn overhead(&self) -> f64 {
        self.per_layer.iter().map(|l| l.predictor + l.comp).sum()
    }
}

/// Analytic FLOP model of blockwise prefill for one model shape.
pub struct CostModel {
    /// Residual stream width.
    pub d_model: f64,
    /// FFN hidden width.
    pub d_ffn: f64,
    /// Transformer layers.
    pub n_layers: usize,
    /// Query heads.
    pub n_heads: f64,
    /// KV heads (GQA).
    pub n_kv_heads: f64,
    /// Per-head dimension.
    pub d_head: f64,
    /// Prefill block size in tokens.
    pub block: usize,
    /// Expert-predictor rank (overhead model).
    pub pred_r: f64,
    /// Compensator rank (overhead model).
    pub comp_r: f64,
}

impl CostModel {
    /// Cost model matching a loaded artifact's model config.
    pub fn from_cfg(cfg: &ModelCfg) -> Self {
        CostModel {
            d_model: cfg.d_model as f64,
            d_ffn: cfg.d_ffn as f64,
            n_layers: cfg.n_layers,
            n_heads: cfg.n_heads as f64,
            n_kv_heads: cfg.n_kv_heads as f64,
            d_head: cfg.d_head as f64,
            block: cfg.block,
            // overhead ranks per the paper: d/16 and d/8
            pred_r: (cfg.d_model / 16) as f64,
            comp_r: (cfg.d_model / 8) as f64,
        }
    }

    /// LLaMA-3.1-8B shape — used to reproduce the paper's headline
    /// figures at the scale the paper reports.
    pub fn llama8b() -> Self {
        CostModel {
            d_model: 4096.0,
            d_ffn: 14336.0,
            n_layers: 32,
            n_heads: 32.0,
            n_kv_heads: 8.0,
            d_head: 128.0,
            block: 128,
            pred_r: 256.0,
            comp_r: 512.0,
        }
    }

    /// LLaMA-3.2-1B shape.
    pub fn llama1b() -> Self {
        CostModel {
            d_model: 2048.0,
            d_ffn: 8192.0,
            n_layers: 16,
            n_heads: 32.0,
            n_kv_heads: 8.0,
            d_head: 64.0,
            block: 128,
            pred_r: 128.0,
            comp_r: 256.0,
        }
    }

    /// LLaMA-3.2-3B shape.
    pub fn llama3b() -> Self {
        CostModel {
            d_model: 3072.0,
            d_ffn: 8192.0,
            n_layers: 28,
            n_heads: 24.0,
            n_kv_heads: 8.0,
            d_head: 128.0,
            block: 128,
            pred_r: 256.0,
            comp_r: 384.0,
        }
    }

    /// One layer's FLOPs for a `t`-token block attending to `s_ctx`
    /// positions, computing `k_ffn` of the d_ffn neurons (dense:
    /// k_ffn = d_ffn, no predictor/compensator overhead).
    pub fn layer_flops(&self, t: usize, s_ctx: usize, k_ffn: f64,
                       sparse_overheads: bool) -> LayerFlops {
        let t = t as f64;
        let s = s_ctx as f64;
        let d = self.d_model;
        let dh = self.d_head;
        let q_dim = self.n_heads * dh;
        let kv_dim = self.n_kv_heads * dh;
        // 2*m*n*k per matmul
        let attn_proj =
            2.0 * t * d * q_dim            // Q
            + 2.0 * 2.0 * t * d * kv_dim   // K, V
            + 2.0 * t * q_dim * d;         // O
        let attn_mix = 2.0 * 2.0 * t * s * q_dim; // QK^T + AV over nh heads
        let ffn = 3.0 * 2.0 * t * d * k_ffn; // gate, up, down
        let (predictor, comp) = if sparse_overheads {
            (
                2.0 * t * d                       // attention pool
                    + 2.0 * d * self.pred_r       // MLP-1 (one vector)
                    + 2.0 * self.pred_r * self.d_ffn, // MLP-2
                2.0 * 2.0 * t * d * self.comp_r,  // comp MLP both layers
            )
        } else {
            (0.0, 0.0)
        };
        LayerFlops { attn_proj, attn_mix, ffn, predictor, comp }
    }

    /// FLOPs of a whole blockwise prefill of `ctx` tokens.
    ///
    /// `layer_k[l]` = FFN width for layer l on *sparse* blocks; the first
    /// and last block run dense when `dense_first`/`dense_last` (paper
    /// §3.4). Dense prefill = all layer_k = d_ffn, overheads off.
    pub fn prefill_flops(&self, ctx: usize, layer_k: &[f64],
                         sparse_overheads: bool, dense_first: bool,
                         dense_last: bool) -> BlockCost {
        assert_eq!(layer_k.len(), self.n_layers);
        let n_blocks = ctx.div_ceil(self.block);
        let mut per_layer = vec![LayerFlops::default(); self.n_layers];
        for b in 0..n_blocks {
            let t = self.block.min(ctx - b * self.block);
            let s_ctx = b * self.block + t;
            let dense_block = (dense_first && b == 0)
                || (dense_last && b == n_blocks - 1);
            for (l, acc) in per_layer.iter_mut().enumerate() {
                let (k, ovh) = if dense_block {
                    (self.d_ffn, false)
                } else {
                    (layer_k[l], sparse_overheads)
                };
                let lf = self.layer_flops(t, s_ctx, k, ovh);
                acc.attn_proj += lf.attn_proj;
                acc.attn_mix += lf.attn_mix;
                acc.ffn += lf.ffn;
                acc.predictor += lf.predictor;
                acc.comp += lf.comp;
            }
        }
        BlockCost { per_layer }
    }

    /// Dense-prefill FLOPs (baseline).
    pub fn dense_prefill(&self, ctx: usize) -> BlockCost {
        let ks = vec![self.d_ffn; self.n_layers];
        self.prefill_flops(ctx, &ks, false, false, false)
    }

    /// Compute-bound speedup of a sparse configuration vs dense
    /// (paper Fig. 7): ratio of total FLOPs.
    pub fn speedup(&self, ctx: usize, layer_density: &[f64],
                   dense_first: bool, dense_last: bool) -> f64 {
        let ks: Vec<f64> =
            layer_density.iter().map(|&b| b * self.d_ffn).collect();
        let dense = self.dense_prefill(ctx).total();
        let sparse = self
            .prefill_flops(ctx, &ks, true, dense_first, dense_last)
            .total();
        dense / sparse
    }

    /// Context length at which attention FLOPs overtake FFN FLOPs in a
    /// dense prefill (paper §2.3: ~28K tokens for the 8B model).
    pub fn attn_ffn_crossover(&self) -> usize {
        let mut lo = self.block;
        let mut hi = 1 << 22;
        while lo < hi {
            let mid = (lo + hi) / 2 / self.block * self.block;
            let mid = mid.max(lo + self.block);
            let c = self.dense_prefill(mid);
            if c.attn() >= c.ffn() {
                hi = mid - self.block;
            } else {
                lo = mid;
            }
            if hi <= lo + self.block {
                break;
            }
        }
        lo
    }
}

/// Roofline translation: FLOPs → seconds at a calibrated throughput.
#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    /// Calibrated effective throughput in FLOP/s.
    pub flops_per_sec: f64,
}

impl Roofline {
    /// Seconds to execute `flops` at the calibrated throughput.
    pub fn project(&self, flops: f64) -> f64 {
        flops / self.flops_per_sec
    }
}

/// Measured wall-clock per abstract scheduler cost unit, maintained as
/// an exponentially-weighted moving average.
///
/// The scheduler's deadline projection needs milliseconds, but the
/// [`crate::router::LoadEstimator`] speaks in abstract units (one
/// prefill block ≈ 1). `UnitClock` bridges the two from *measurement*:
/// the executor feeds it every (units, elapsed-ms) observation and asks
/// for projections over a session's remaining steps. Until the first
/// observation lands, [`UnitClock::project_ms`] returns `None` and the
/// scheduler stays conservative (no deadline-based preemption).
///
/// ```
/// use fastforward::cost::UnitClock;
///
/// let mut clock = UnitClock::new(0.5);
/// assert!(clock.project_ms(10.0).is_none(), "unprimed: no estimate");
/// clock.observe(1.0, 8.0); // one block step took 8 ms
/// clock.observe(1.0, 12.0);
/// let p = clock.project_ms(10.0).unwrap();
/// assert!(p > 80.0 && p < 120.0, "projection tracks the EWMA: {p}");
/// ```
#[derive(Debug, Clone)]
pub struct UnitClock {
    ms_per_unit: Option<f64>,
    alpha: f64,
}

impl UnitClock {
    /// New clock with EWMA smoothing factor `alpha` in (0, 1]; higher
    /// alpha adapts faster, lower alpha is steadier.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha out of (0, 1]");
        UnitClock {
            ms_per_unit: None,
            alpha,
        }
    }

    /// Fold in one measurement: `units` of scheduler cost took `ms`
    /// milliseconds of wall-clock. Non-positive units are ignored.
    pub fn observe(&mut self, units: f64, ms: f64) {
        if units <= 0.0 || !ms.is_finite() || ms < 0.0 {
            return;
        }
        let sample = ms / units;
        self.ms_per_unit = Some(match self.ms_per_unit {
            None => sample,
            Some(prev) => prev + self.alpha * (sample - prev),
        });
    }

    /// Projected milliseconds for `units` more scheduler cost, or
    /// `None` before any observation.
    pub fn project_ms(&self, units: f64) -> Option<f64> {
        self.ms_per_unit.map(|m| m * units.max(0.0))
    }

    /// Current EWMA in ms per unit, if primed.
    pub fn ms_per_unit(&self) -> Option<f64> {
        self.ms_per_unit
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn ffn_dominates_short_context_8b() {
        let m = CostModel::llama8b();
        let c = m.dense_prefill(2048);
        assert!(
            c.ffn() > c.attn(),
            "FFN should dominate at 2K: ffn={:.3e} attn={:.3e}",
            c.ffn(),
            c.attn()
        );
    }

    #[test]
    fn crossover_near_paper_value_8b() {
        // paper §1: FFN dominates until ~28K tokens on Llama-3.1-8B
        let m = CostModel::llama8b();
        let x = m.attn_ffn_crossover();
        assert!(
            (16_000..48_000).contains(&x),
            "crossover {x} should be in the ~28K regime"
        );
    }

    #[test]
    fn crossover_smaller_model_is_earlier() {
        let x1 = CostModel::llama1b().attn_ffn_crossover();
        let x8 = CostModel::llama8b().attn_ffn_crossover();
        assert!(x1 < x8, "1B crossover {x1} should precede 8B {x8}");
    }

    #[test]
    fn speedup_peaks_mid_context() {
        // paper Fig. 7: modest at short ctx (dense first/last blocks),
        // peak ~2-8K, decaying toward 1 as attention dominates
        let m = CostModel::llama8b();
        let dens = vec![0.5; m.n_layers];
        let s_short = m.speedup(256, &dens, true, true);
        let s_mid = m.speedup(4096, &dens, true, true);
        let s_long = m.speedup(262_144, &dens, true, true);
        assert!(s_mid > s_short, "mid {s_mid} > short {s_short}");
        assert!(s_mid > s_long, "mid {s_mid} > long {s_long}");
        assert!(s_mid > 1.2 && s_mid < 2.0, "mid speedup {s_mid}");
        assert!(s_long < 1.15, "long-ctx speedup decays: {s_long}");
    }

    #[test]
    fn speedup_50pct_in_paper_band() {
        // paper: up to 1.45x at 50% sparsity for mid contexts
        let m = CostModel::llama8b();
        let dens = vec![0.5; m.n_layers];
        let mut best = 0.0f64;
        for ctx in [1024, 2048, 4096, 8192] {
            best = best.max(m.speedup(ctx, &dens, true, true));
        }
        assert!(
            (1.25..1.60).contains(&best),
            "peak speedup {best} should be ~1.45x"
        );
    }

    #[test]
    fn prop_speedup_bounds() {
        check("speedup-bounds", 100, |r| {
            let m = CostModel::llama1b();
            let dens: Vec<f64> =
                (0..m.n_layers).map(|_| 0.3 + r.f64() * 0.7).collect();
            let ctx = 128 * r.range(1, 128);
            let s = m.speedup(ctx, &dens, true, true);
            crate::prop_assert!(s >= 0.95, "speedup {s} collapsed");
            crate::prop_assert!(s < 3.4, "speedup {s} impossible");
            Ok(())
        });
    }

    #[test]
    fn prop_denser_is_slower() {
        check("denser-slower", 60, |r| {
            let m = CostModel::llama3b();
            let ctx = 128 * r.range(4, 64);
            let d1 = 0.3 + r.f64() * 0.3;
            let d2 = d1 + 0.2;
            let s1 = m.speedup(ctx, &vec![d1; m.n_layers], true, true);
            let s2 = m.speedup(ctx, &vec![d2; m.n_layers], true, true);
            crate::prop_assert!(
                s1 >= s2 - 1e-9,
                "sparser should speed up more: {s1} vs {s2}"
            );
            Ok(())
        });
    }

    #[test]
    fn dense_blocks_reduce_speedup_at_short_ctx() {
        let m = CostModel::llama8b();
        let dens = vec![0.5; m.n_layers];
        let with = m.speedup(512, &dens, true, true);
        let without = m.speedup(512, &dens, false, false);
        assert!(without > with);
    }

    #[test]
    fn overheads_are_small() {
        let m = CostModel::llama8b();
        let ks = vec![m.d_ffn * 0.5; m.n_layers];
        let c = m.prefill_flops(4096, &ks, true, true, true);
        assert!(c.overhead() < 0.05 * c.total(),
                "predictor+comp overhead should be <5%: {:.3}%",
                100.0 * c.overhead() / c.total());
    }

    #[test]
    fn unit_clock_ewma_and_projection() {
        let mut c = UnitClock::new(0.5);
        assert!(c.project_ms(5.0).is_none());
        assert!(c.ms_per_unit().is_none());
        c.observe(2.0, 20.0); // 10 ms/unit seed
        assert!((c.ms_per_unit().unwrap() - 10.0).abs() < 1e-12);
        c.observe(1.0, 20.0); // ewma: 10 + 0.5*(20-10) = 15
        assert!((c.ms_per_unit().unwrap() - 15.0).abs() < 1e-12);
        assert!((c.project_ms(4.0).unwrap() - 60.0).abs() < 1e-9);
        // garbage observations are ignored
        c.observe(0.0, 99.0);
        c.observe(1.0, f64::NAN);
        c.observe(1.0, -3.0);
        assert!((c.ms_per_unit().unwrap() - 15.0).abs() < 1e-12);
        // negative projections clamp to zero units
        assert_eq!(c.project_ms(-2.0).unwrap(), 0.0);
    }
}
