//! Serving metrics: TTFT / TPOT / throughput histograms with a
//! Prometheus-text exporter (hand-rolled; substrate for the absent
//! metrics crates).

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Summary;

#[derive(Default)]
struct Inner {
    ttft_ms: Summary,
    tpot_ms: Summary,
    e2e_ms: Summary,
    prompt_tokens: u64,
    generated_tokens: u64,
    requests_completed: u64,
    requests_rejected: u64,
    blocks_dense: u64,
    blocks_sparse: u64,
}

/// Thread-safe metrics registry shared by router/engine/server.
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            inner: Mutex::new(Inner::default()),
            started: Instant::now(),
        }
    }

    pub fn record_ttft(&self, ms: f64) {
        self.inner.lock().unwrap().ttft_ms.add(ms);
    }

    pub fn record_tpot(&self, ms: f64) {
        self.inner.lock().unwrap().tpot_ms.add(ms);
    }

    pub fn record_request(&self, prompt_tokens: usize, generated: usize,
                          e2e_ms: f64) {
        let mut g = self.inner.lock().unwrap();
        g.prompt_tokens += prompt_tokens as u64;
        g.generated_tokens += generated as u64;
        g.requests_completed += 1;
        g.e2e_ms.add(e2e_ms);
    }

    pub fn record_rejection(&self) {
        self.inner.lock().unwrap().requests_rejected += 1;
    }

    pub fn record_block(&self, dense: bool) {
        let mut g = self.inner.lock().unwrap();
        if dense {
            g.blocks_dense += 1;
        } else {
            g.blocks_sparse += 1;
        }
    }

    pub fn ttft_p50_p95(&self) -> (f64, f64) {
        let g = self.inner.lock().unwrap();
        (g.ttft_ms.percentile(50.0), g.ttft_ms.percentile(95.0))
    }

    pub fn requests_completed(&self) -> u64 {
        self.inner.lock().unwrap().requests_completed
    }

    /// Prometheus text exposition format.
    pub fn export(&self) -> String {
        let g = self.inner.lock().unwrap();
        let up = self.started.elapsed().as_secs_f64();
        let mut out = String::new();
        let mut gauge = |name: &str, help: &str, v: f64| {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"
            ));
        };
        gauge("ff_uptime_seconds", "process uptime", up);
        gauge("ff_requests_completed", "completed requests",
              g.requests_completed as f64);
        gauge("ff_requests_rejected", "rejected (backpressure)",
              g.requests_rejected as f64);
        gauge("ff_prompt_tokens_total", "prefilled tokens",
              g.prompt_tokens as f64);
        gauge("ff_generated_tokens_total", "decoded tokens",
              g.generated_tokens as f64);
        gauge("ff_blocks_dense_total", "dense prefill blocks",
              g.blocks_dense as f64);
        gauge("ff_blocks_sparse_total", "sparse prefill blocks",
              g.blocks_sparse as f64);
        for (name, s) in [
            ("ff_ttft_ms", &g.ttft_ms),
            ("ff_tpot_ms", &g.tpot_ms),
            ("ff_e2e_ms", &g.e2e_ms),
        ] {
            if !s.is_empty() {
                gauge(&format!("{name}_mean"), "mean", s.mean());
                gauge(&format!("{name}_p50"), "median", s.percentile(50.0));
                gauge(&format!("{name}_p95"), "p95", s.percentile(95.0));
                gauge(&format!("{name}_p99"), "p99", s.percentile(99.0));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_exports() {
        let m = Metrics::new();
        m.record_ttft(10.0);
        m.record_ttft(20.0);
        m.record_tpot(2.0);
        m.record_request(512, 32, 600.0);
        m.record_block(true);
        m.record_block(false);
        let (p50, p95) = m.ttft_p50_p95();
        assert!((p50 - 15.0).abs() < 1e-9);
        assert!(p95 > p50);
        let text = m.export();
        assert!(text.contains("ff_ttft_ms_mean 15"));
        assert!(text.contains("ff_requests_completed 1"));
        assert!(text.contains("ff_blocks_sparse_total 1"));
    }

    #[test]
    fn thread_safety() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let hs: Vec<_> = (0..8)
            .map(|i| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for j in 0..100 {
                        m.record_ttft((i * 100 + j) as f64);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        let g = m.export();
        assert!(g.contains("ff_ttft_ms_mean"));
    }
}
