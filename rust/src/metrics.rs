//! Serving metrics: TTFT / TPOT / ITL / throughput histograms,
//! per-class queue-delay histograms, scheduler preemption counters,
//! per-replica dispatch counters and prefix-cache gauges, with a
//! Prometheus-text exporter (hand-rolled; substrate for the absent
//! metrics crates).
//!
//! Every series is documented in docs/OPERATIONS.md — keep the two in
//! sync when adding series.

use std::sync::Mutex;
use std::time::Instant;

use crate::kvcache::PrefixCacheStats;
use crate::router::SloClass;
use crate::util::stats::Summary;

/// Per-replica dispatch/completion counters.
#[derive(Default, Clone)]
struct ReplicaCounters {
    dispatched: u64,
    completed: u64,
    failed: u64,
}

/// How the cluster front placed a request on a worker — the label of
/// `ff_cluster_dispatch_total`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterRoute {
    /// Landed on the consistent-hash affine worker (prefix likely warm).
    Affine,
    /// Affine worker saturated/dead — least-loaded fallback.
    Fallback,
    /// Random dispatch baseline (`--dispatch random`).
    Random,
}

/// One backplane worker's health/inflight gauge pair.
#[derive(Default, Clone)]
struct WorkerGauges {
    healthy: bool,
    inflight: u64,
}

/// Cluster front-tier counters — populated only by `ff cluster`; the
/// whole `ff_cluster_*` block stays out of the exposition until
/// [`Metrics::ensure_cluster_workers`] registers a worker table.
#[derive(Default)]
struct ClusterCounters {
    dispatch_affine: u64,
    dispatch_fallback: u64,
    dispatch_random: u64,
    sheds_429: u64,
    sheds_503: u64,
    quota_rejects: u64,
    backplane_errors: u64,
    retries: u64,
    workers: Vec<WorkerGauges>,
}

#[derive(Default)]
struct Inner {
    ttft_ms: Summary,
    tpot_ms: Summary,
    e2e_ms: Summary,
    /// Inter-token latency (wall-clock between consecutive streamed
    /// token emissions), per SLO class: [interactive, batch].
    itl_ms: [Summary; 2],
    /// Queue delay (submit → executor admission), per SLO class.
    queue_delay_ms: [Summary; 2],
    prompt_tokens: u64,
    generated_tokens: u64,
    requests_completed: u64,
    requests_rejected: u64,
    /// Batch-class prefills paused so interactive work runs first.
    preemptions: u64,
    /// Preempted prefills ejected back to the queue under KV pressure
    /// (their computed blocks salvaged into the prefix cache).
    preemption_ejections: u64,
    /// Requests cancelled by the executor (client disconnect or
    /// explicit cancellation).
    cancelled: u64,
    /// SSE streams whose client went away mid-stream.
    stream_disconnects: u64,
    blocks_dense: u64,
    blocks_sparse: u64,
    tail_tokens: u64,
    /// Rows per batched forward pass (decode rows + prefill chunk) —
    /// the continuous-batching occupancy histogram.
    batch_occupancy: Summary,
    /// Batched forward passes executed.
    batch_steps: u64,
    /// Sequence rows folded across all batched passes.
    batch_rows: u64,
    replicas: Vec<ReplicaCounters>,
    /// Requests re-routed off a dead replica's queue to a survivor.
    failover_rerouted: u64,
    /// Dead-replica requests no survivor could absorb (errored back).
    failover_failed: u64,
    cluster: ClusterCounters,
    /// Latest snapshot of the prefix cache's own counters — the cache
    /// is the single source of truth; the executor pushes snapshots
    /// after lookups and inserts.
    prefix: PrefixCacheStats,
    prefix_bytes: u64,
    prefix_entries: u64,
}

/// Thread-safe metrics registry shared by router/pool/engine/server.
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    /// Fresh registry; uptime starts now.
    pub fn new() -> Self {
        Metrics {
            inner: Mutex::new(Inner::default()),
            started: Instant::now(),
        }
    }

    /// Record one request's time-to-first-token.
    pub fn record_ttft(&self, ms: f64) {
        self.inner.lock().unwrap().ttft_ms.add(ms);
    }

    /// Record one decode step's latency.
    pub fn record_tpot(&self, ms: f64) {
        self.inner.lock().unwrap().tpot_ms.add(ms);
    }

    fn class_idx(class: SloClass) -> usize {
        if class.is_interactive() {
            0
        } else {
            1
        }
    }

    /// Record one inter-token interval (time between consecutive token
    /// emissions on a request's stream) for the given SLO class.
    pub fn record_itl(&self, class: SloClass, ms: f64) {
        self.inner.lock().unwrap().itl_ms[Self::class_idx(class)].add(ms);
    }

    /// Record one request's queue delay (submission → executor
    /// admission) for the given SLO class.
    pub fn record_queue_delay(&self, class: SloClass, ms: f64) {
        self.inner.lock().unwrap().queue_delay_ms[Self::class_idx(class)]
            .add(ms);
    }

    /// Number of queue-delay samples recorded for a class so far — one
    /// per first admission (an ejected-and-readmitted request is not
    /// re-sampled). Monotonically nondecreasing; the randomized
    /// concurrency suite asserts exactly that.
    pub fn queue_delay_samples(&self, class: SloClass) -> usize {
        self.inner.lock().unwrap().queue_delay_ms[Self::class_idx(class)]
            .len()
    }

    /// Record a batch-class prefill being paused for interactive work.
    pub fn record_preemption(&self) {
        self.inner.lock().unwrap().preemptions += 1;
    }

    /// Record a preempted prefill ejected back to its queue under KV
    /// pressure (resumable via the prefix cache).
    pub fn record_preemption_ejection(&self) {
        self.inner.lock().unwrap().preemption_ejections += 1;
    }

    /// Record a request cancelled before completion.
    pub fn record_cancelled(&self) {
        self.inner.lock().unwrap().cancelled += 1;
    }

    /// Record an SSE client that went away mid-stream.
    pub fn record_stream_disconnect(&self) {
        self.inner.lock().unwrap().stream_disconnects += 1;
    }

    /// Batch-prefill preemptions so far.
    pub fn preemptions(&self) -> u64 {
        self.inner.lock().unwrap().preemptions
    }

    /// Requests cancelled by the executor so far.
    pub fn cancelled(&self) -> u64 {
        self.inner.lock().unwrap().cancelled
    }

    /// Mid-stream client disconnects so far.
    pub fn stream_disconnects(&self) -> u64 {
        self.inner.lock().unwrap().stream_disconnects
    }

    /// (p50, p95) of inter-token latency samples for a class.
    pub fn itl_p50_p95(&self, class: SloClass) -> (f64, f64) {
        let g = self.inner.lock().unwrap();
        let s = &g.itl_ms[Self::class_idx(class)];
        (s.percentile(50.0), s.percentile(95.0))
    }

    /// Record a completed request (token counts + end-to-end latency).
    pub fn record_request(&self, prompt_tokens: usize, generated: usize,
                          e2e_ms: f64) {
        let mut g = self.inner.lock().unwrap();
        g.prompt_tokens += prompt_tokens as u64;
        g.generated_tokens += generated as u64;
        g.requests_completed += 1;
        g.e2e_ms.add(e2e_ms);
    }

    /// Record an admission rejection (backpressure).
    pub fn record_rejection(&self) {
        self.inner.lock().unwrap().requests_rejected += 1;
    }

    /// Record one batched forward pass of `occupancy` sequence rows
    /// (decode rows plus the prefill chunk that rode along) — the
    /// samples behind `ff_batch_occupancy`.
    pub fn record_batch_step(&self, occupancy: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batch_occupancy.add(occupancy as f64);
        g.batch_steps += 1;
        g.batch_rows += occupancy as u64;
    }

    /// Batched forward passes executed so far.
    pub fn batch_steps(&self) -> u64 {
        self.inner.lock().unwrap().batch_steps
    }

    /// Sequence rows folded across all batched passes so far.
    pub fn batch_rows(&self) -> u64 {
        self.inner.lock().unwrap().batch_rows
    }

    /// Mean rows per batched pass (0.0 before the first pass) — the
    /// scalar the scheduler regression suite asserts is monotone in
    /// offered load.
    pub fn batch_occupancy_mean(&self) -> f64 {
        let g = self.inner.lock().unwrap();
        if g.batch_occupancy.is_empty() {
            0.0
        } else {
            g.batch_occupancy.mean()
        }
    }

    /// Fold one finished prefill's block counts into the registry.
    /// `timing.blocks` only counts blocks actually *executed*, so
    /// prefix-cache adoptions never inflate the execution counters.
    pub fn record_prefill_timing(
        &self,
        timing: &crate::engine::PrefillTiming,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.blocks_dense += timing.dense_blocks as u64;
        g.blocks_sparse +=
            (timing.blocks - timing.dense_blocks) as u64;
        g.tail_tokens += timing.tail_tokens as u64;
    }

    /// Size the per-replica counter table (idempotent; grows only).
    pub fn ensure_replicas(&self, n: usize) {
        let mut g = self.inner.lock().unwrap();
        if g.replicas.len() < n {
            g.replicas.resize(n, ReplicaCounters::default());
        }
    }

    /// Record a request dispatched to replica `id`.
    pub fn record_replica_dispatch(&self, id: usize) {
        let mut g = self.inner.lock().unwrap();
        if g.replicas.len() <= id {
            g.replicas.resize(id + 1, ReplicaCounters::default());
        }
        g.replicas[id].dispatched += 1;
    }

    /// Record a request finished on replica `id` (`ok` = no error).
    pub fn record_replica_done(&self, id: usize, ok: bool) {
        let mut g = self.inner.lock().unwrap();
        if g.replicas.len() <= id {
            g.replicas.resize(id + 1, ReplicaCounters::default());
        }
        if ok {
            g.replicas[id].completed += 1;
        } else {
            g.replicas[id].failed += 1;
        }
    }

    /// Record a dead replica's queue fail-over: `rerouted` requests
    /// re-admitted on survivors, `failed` errored back to clients.
    pub fn record_failover(&self, rerouted: u64, failed: u64) {
        let mut g = self.inner.lock().unwrap();
        g.failover_rerouted += rerouted;
        g.failover_failed += failed;
    }

    /// `(rerouted, failed)` fail-over counts so far.
    pub fn failover_counts(&self) -> (u64, u64) {
        let g = self.inner.lock().unwrap();
        (g.failover_rerouted, g.failover_failed)
    }

    /// Size the cluster worker gauge table (idempotent; grows only).
    /// Registering any worker turns on the `ff_cluster_*` exposition
    /// block.
    pub fn ensure_cluster_workers(&self, n: usize) {
        let mut g = self.inner.lock().unwrap();
        if g.cluster.workers.len() < n {
            g.cluster.workers.resize(n, WorkerGauges::default());
        }
    }

    /// Record one cluster dispatch decision.
    pub fn record_cluster_dispatch(&self, route: ClusterRoute) {
        let mut g = self.inner.lock().unwrap();
        match route {
            ClusterRoute::Affine => g.cluster.dispatch_affine += 1,
            ClusterRoute::Fallback => g.cluster.dispatch_fallback += 1,
            ClusterRoute::Random => g.cluster.dispatch_random += 1,
        }
    }

    /// `(affine, fallback, random)` cluster dispatch counts so far.
    pub fn cluster_dispatches(&self) -> (u64, u64, u64) {
        let g = self.inner.lock().unwrap();
        (
            g.cluster.dispatch_affine,
            g.cluster.dispatch_fallback,
            g.cluster.dispatch_random,
        )
    }

    /// Record a request shed at the cluster front (`status` ∈ {429,
    /// 503}; anything else counts toward 503).
    pub fn record_cluster_shed(&self, status: u16) {
        let mut g = self.inner.lock().unwrap();
        if status == 429 {
            g.cluster.sheds_429 += 1;
        } else {
            g.cluster.sheds_503 += 1;
        }
    }

    /// `(sheds_429, sheds_503)` cluster load-shed counts so far.
    pub fn cluster_sheds(&self) -> (u64, u64) {
        let g = self.inner.lock().unwrap();
        (g.cluster.sheds_429, g.cluster.sheds_503)
    }

    /// Record a request refused by per-tenant quota (subset of 429
    /// sheds, counted separately so quota pressure is visible).
    pub fn record_cluster_quota_reject(&self) {
        self.inner.lock().unwrap().cluster.quota_rejects += 1;
    }

    /// Record a backplane I/O failure against a worker (connect/write/
    /// proxy error — not an HTTP-level rejection).
    pub fn record_cluster_backplane_error(&self) {
        self.inner.lock().unwrap().cluster.backplane_errors += 1;
    }

    /// Record a dispatch retried on another worker after a backplane
    /// failure.
    pub fn record_cluster_retry(&self) {
        self.inner.lock().unwrap().cluster.retries += 1;
    }

    /// Update worker `id`'s health/inflight gauges (health-checker +
    /// proxy bookkeeping).
    pub fn set_cluster_worker(&self, id: usize, healthy: bool,
                              inflight: usize) {
        let mut g = self.inner.lock().unwrap();
        if g.cluster.workers.len() <= id {
            g.cluster.workers.resize(id + 1, WorkerGauges::default());
        }
        g.cluster.workers[id] =
            WorkerGauges { healthy, inflight: inflight as u64 };
    }

    /// Push the latest prefix-cache snapshot (counters + residency).
    /// Called by the executor after lookups and inserts while it holds
    /// the cache lock, so the exported series never drift from the
    /// cache's own accounting.
    pub fn set_prefix_state(&self, stats: PrefixCacheStats, bytes: usize,
                            entries: usize) {
        let mut g = self.inner.lock().unwrap();
        g.prefix = stats;
        g.prefix_bytes = bytes as u64;
        g.prefix_entries = entries as u64;
    }

    /// (p50, p95) of recorded TTFT samples.
    pub fn ttft_p50_p95(&self) -> (f64, f64) {
        let g = self.inner.lock().unwrap();
        (g.ttft_ms.percentile(50.0), g.ttft_ms.percentile(95.0))
    }

    /// Requests completed so far.
    pub fn requests_completed(&self) -> u64 {
        self.inner.lock().unwrap().requests_completed
    }

    /// Total prefill blocks actually executed (dense + sparse). The
    /// engine's block-execution counter: blocks adopted from the prefix
    /// cache never pass through here, so the difference between prompt
    /// blocks submitted and this counter is exactly the compute skipped.
    pub fn blocks_executed(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        g.blocks_dense + g.blocks_sparse
    }

    /// Prefix-cache (hits, misses, blocks_reused) counters from the
    /// latest snapshot.
    pub fn prefix_counters(&self) -> (u64, u64, u64) {
        let g = self.inner.lock().unwrap();
        (g.prefix.hits, g.prefix.misses, g.prefix.blocks_reused)
    }

    /// Prometheus text exposition format.
    pub fn export(&self) -> String {
        let g = self.inner.lock().unwrap();
        let up = self.started.elapsed().as_secs_f64();
        let mut out = String::new();
        let mut gauge = |name: &str, help: &str, v: f64| {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"
            ));
        };
        gauge("ff_uptime_seconds", "process uptime", up);
        gauge("ff_requests_completed", "completed requests",
              g.requests_completed as f64);
        gauge("ff_requests_rejected", "rejected (backpressure)",
              g.requests_rejected as f64);
        gauge("ff_preemptions_total",
              "batch prefills paused for interactive work",
              g.preemptions as f64);
        gauge("ff_preemption_ejections_total",
              "preempted prefills ejected to queue under KV pressure",
              g.preemption_ejections as f64);
        gauge("ff_cancelled_total", "requests cancelled before completion",
              g.cancelled as f64);
        gauge("ff_stream_disconnects_total",
              "SSE clients gone mid-stream",
              g.stream_disconnects as f64);
        gauge("ff_prompt_tokens_total", "prefilled tokens",
              g.prompt_tokens as f64);
        gauge("ff_generated_tokens_total", "decoded tokens",
              g.generated_tokens as f64);
        gauge("ff_blocks_dense_total", "dense prefill blocks executed",
              g.blocks_dense as f64);
        gauge("ff_blocks_sparse_total", "sparse prefill blocks executed",
              g.blocks_sparse as f64);
        gauge("ff_prefill_tail_tokens_total",
              "ragged-tail tokens prefilled through T=1 steps",
              g.tail_tokens as f64);
        gauge("ff_batch_steps_total",
              "batched forward passes executed",
              g.batch_steps as f64);
        gauge("ff_batch_rows_total",
              "sequence rows folded across batched passes",
              g.batch_rows as f64);
        if !g.batch_occupancy.is_empty() {
            gauge("ff_batch_occupancy",
                  "mean rows per batched forward pass",
                  g.batch_occupancy.mean());
            gauge("ff_batch_occupancy_p50",
                  "median rows per batched forward pass",
                  g.batch_occupancy.percentile(50.0));
            gauge("ff_batch_occupancy_max",
                  "largest batched forward pass",
                  g.batch_occupancy.max());
        }
        gauge("ff_prefix_hits_total", "prefills that adopted a cached prefix",
              g.prefix.hits as f64);
        gauge("ff_prefix_misses_total", "prefills with no cached prefix",
              g.prefix.misses as f64);
        gauge("ff_prefix_blocks_reused_total",
              "prefill blocks skipped via prefix adoption",
              g.prefix.blocks_reused as f64);
        gauge("ff_prefix_insertions_total", "prefix block entries stored",
              g.prefix.insertions as f64);
        gauge("ff_prefix_evictions_total", "prefix entries evicted (LRU)",
              g.prefix.evictions as f64);
        gauge("ff_prefix_cache_bytes", "prefix cache resident KV bytes",
              g.prefix_bytes as f64);
        gauge("ff_prefix_cache_entries", "prefix cache resident entries",
              g.prefix_entries as f64);
        for (name, s) in [
            ("ff_ttft_ms", &g.ttft_ms),
            ("ff_tpot_ms", &g.tpot_ms),
            ("ff_e2e_ms", &g.e2e_ms),
        ] {
            if !s.is_empty() {
                gauge(&format!("{name}_mean"), "mean", s.mean());
                gauge(&format!("{name}_p50"), "median", s.percentile(50.0));
                gauge(&format!("{name}_p95"), "p95", s.percentile(95.0));
                gauge(&format!("{name}_p99"), "p99", s.percentile(99.0));
            }
        }
        // Per-class latency summaries use Prometheus labels: one
        // HELP/TYPE block per metric name, then one labeled sample per
        // class (duplicate HELP lines are a text-exposition parse
        // error).
        for (name, help, pair) in [
            (
                "ff_itl_ms",
                "inter-token latency between streamed emissions",
                &g.itl_ms,
            ),
            (
                "ff_queue_delay_ms",
                "submit-to-admission queue delay",
                &g.queue_delay_ms,
            ),
        ] {
            if pair.iter().all(|s| s.is_empty()) {
                continue;
            }
            for stat in ["mean", "p50", "p95", "p99"] {
                out.push_str(&format!(
                    "# HELP {name}_{stat} {help}\n\
                     # TYPE {name}_{stat} gauge\n"
                ));
                for (class, s) in
                    ["interactive", "batch"].iter().zip(pair)
                {
                    if s.is_empty() {
                        continue;
                    }
                    let v = match stat {
                        "mean" => s.mean(),
                        "p50" => s.percentile(50.0),
                        "p95" => s.percentile(95.0),
                        _ => s.percentile(99.0),
                    };
                    out.push_str(&format!(
                        "{name}_{stat}{{class=\"{class}\"}} {v}\n"
                    ));
                }
            }
        }
        // Per-replica series use Prometheus labels so dashboards can
        // aggregate across any pool size.
        for (metric, help, get) in [
            (
                "ff_replica_dispatched_total",
                "requests dispatched to this replica",
                (|c: &ReplicaCounters| c.dispatched)
                    as fn(&ReplicaCounters) -> u64,
            ),
            (
                "ff_replica_completed_total",
                "requests completed by this replica",
                |c: &ReplicaCounters| c.completed,
            ),
            (
                "ff_replica_failed_total",
                "requests failed on this replica",
                |c: &ReplicaCounters| c.failed,
            ),
        ] {
            if g.replicas.is_empty() {
                continue;
            }
            out.push_str(&format!(
                "# HELP {metric} {help}\n# TYPE {metric} gauge\n"
            ));
            for (i, c) in g.replicas.iter().enumerate() {
                out.push_str(&format!(
                    "{metric}{{replica=\"{i}\"}} {}\n",
                    get(c)
                ));
            }
        }
        if g.failover_rerouted + g.failover_failed > 0 {
            gauge("ff_failover_rerouted_total",
                  "dead-replica requests re-routed to survivors",
                  g.failover_rerouted as f64);
            gauge("ff_failover_failed_total",
                  "dead-replica requests no survivor could absorb",
                  g.failover_failed as f64);
        }
        // Cluster front-tier block: only `ff cluster` registers workers,
        // so a plain `serve` exposition never carries empty series.
        if !g.cluster.workers.is_empty() {
            let c = &g.cluster;
            out.push_str(
                "# HELP ff_cluster_dispatch_total requests placed on a \
                 worker, by route\n\
                 # TYPE ff_cluster_dispatch_total gauge\n",
            );
            for (route, v) in [
                ("affine", c.dispatch_affine),
                ("fallback", c.dispatch_fallback),
                ("random", c.dispatch_random),
            ] {
                out.push_str(&format!(
                    "ff_cluster_dispatch_total{{route=\"{route}\"}} {v}\n"
                ));
            }
            let total =
                c.dispatch_affine + c.dispatch_fallback + c.dispatch_random;
            gauge("ff_cluster_affinity_hit_rate",
                  "fraction of dispatches that landed affine",
                  if total > 0 {
                      c.dispatch_affine as f64 / total as f64
                  } else {
                      0.0
                  });
            out.push_str(
                "# HELP ff_cluster_sheds_total requests shed at the \
                 front, by status code\n\
                 # TYPE ff_cluster_sheds_total gauge\n",
            );
            for (code, v) in [("429", c.sheds_429), ("503", c.sheds_503)] {
                out.push_str(&format!(
                    "ff_cluster_sheds_total{{code=\"{code}\"}} {v}\n"
                ));
            }
            gauge("ff_cluster_quota_rejects_total",
                  "requests refused by per-tenant quota",
                  c.quota_rejects as f64);
            gauge("ff_cluster_backplane_errors_total",
                  "backplane I/O failures against workers",
                  c.backplane_errors as f64);
            gauge("ff_cluster_retries_total",
                  "dispatches retried on another worker",
                  c.retries as f64);
            for (metric, help, get) in [
                (
                    "ff_cluster_worker_healthy",
                    "worker passes health checks (1/0)",
                    (|w: &WorkerGauges| w.healthy as u64)
                        as fn(&WorkerGauges) -> u64,
                ),
                (
                    "ff_cluster_worker_inflight",
                    "requests currently proxied to this worker",
                    |w: &WorkerGauges| w.inflight,
                ),
            ] {
                out.push_str(&format!(
                    "# HELP {metric} {help}\n# TYPE {metric} gauge\n"
                ));
                for (i, w) in c.workers.iter().enumerate() {
                    out.push_str(&format!(
                        "{metric}{{worker=\"{i}\"}} {}\n",
                        get(w)
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_exports() {
        let m = Metrics::new();
        m.record_ttft(10.0);
        m.record_ttft(20.0);
        m.record_tpot(2.0);
        m.record_request(512, 32, 600.0);
        m.record_prefill_timing(&crate::engine::PrefillTiming {
            blocks: 2,
            dense_blocks: 1,
            tail_tokens: 3,
            ..Default::default()
        });
        let (p50, p95) = m.ttft_p50_p95();
        assert!((p50 - 15.0).abs() < 1e-9);
        assert!(p95 > p50);
        let text = m.export();
        assert!(text.contains("ff_ttft_ms_mean 15"));
        assert!(text.contains("ff_requests_completed 1"));
        assert!(text.contains("ff_blocks_dense_total 1"));
        assert!(text.contains("ff_blocks_sparse_total 1"));
        assert!(text.contains("ff_prefill_tail_tokens_total 3"));
        assert_eq!(m.blocks_executed(), 2);
    }

    #[test]
    fn replica_and_prefix_series() {
        let m = Metrics::new();
        m.ensure_replicas(2);
        m.record_replica_dispatch(0);
        m.record_replica_dispatch(1);
        m.record_replica_dispatch(1);
        m.record_replica_done(1, true);
        m.record_replica_done(0, false);
        m.set_prefix_state(
            PrefixCacheStats {
                hits: 1,
                misses: 1,
                blocks_reused: 3,
                insertions: 4,
                evictions: 1,
            },
            4096,
            2,
        );
        let text = m.export();
        assert!(text.contains("ff_replica_dispatched_total{replica=\"0\"} 1"));
        assert!(text.contains("ff_replica_dispatched_total{replica=\"1\"} 2"));
        assert!(text.contains("ff_replica_completed_total{replica=\"1\"} 1"));
        assert!(text.contains("ff_replica_failed_total{replica=\"0\"} 1"));
        assert!(text.contains("ff_prefix_hits_total 1"));
        assert!(text.contains("ff_prefix_blocks_reused_total 3"));
        assert!(text.contains("ff_prefix_insertions_total 4"));
        assert!(text.contains("ff_prefix_cache_bytes 4096"));
        assert_eq!(m.prefix_counters(), (1, 1, 3));
    }

    #[test]
    fn batch_occupancy_series() {
        let m = Metrics::new();
        assert_eq!(m.batch_occupancy_mean(), 0.0, "empty → 0");
        assert!(!m.export().contains("ff_batch_occupancy "),
                "no occupancy gauge before the first pass");
        m.record_batch_step(1);
        m.record_batch_step(3);
        m.record_batch_step(5);
        assert_eq!(m.batch_steps(), 3);
        assert_eq!(m.batch_rows(), 9);
        assert!((m.batch_occupancy_mean() - 3.0).abs() < 1e-9);
        let text = m.export();
        assert!(text.contains("ff_batch_steps_total 3"));
        assert!(text.contains("ff_batch_rows_total 9"));
        assert!(text.contains("ff_batch_occupancy 3"));
        assert!(text.contains("ff_batch_occupancy_max 5"));
    }

    #[test]
    fn slo_and_streaming_series() {
        let m = Metrics::new();
        m.record_itl(SloClass::Interactive, 2.0);
        m.record_itl(SloClass::Interactive, 4.0);
        m.record_itl(SloClass::Batch, 9.0);
        m.record_queue_delay(SloClass::Interactive, 1.0);
        m.record_queue_delay(SloClass::Batch, 30.0);
        m.record_preemption();
        m.record_preemption();
        m.record_preemption_ejection();
        m.record_cancelled();
        m.record_stream_disconnect();
        assert_eq!(m.preemptions(), 2);
        assert_eq!(m.cancelled(), 1);
        assert_eq!(m.stream_disconnects(), 1);
        let (p50, p95) = m.itl_p50_p95(SloClass::Interactive);
        assert!((p50 - 3.0).abs() < 1e-9);
        assert!(p95 > p50);
        let text = m.export();
        assert!(text.contains("ff_preemptions_total 2"));
        assert!(text.contains("ff_preemption_ejections_total 1"));
        assert!(text.contains("ff_cancelled_total 1"));
        assert!(text.contains("ff_stream_disconnects_total 1"));
        assert!(text.contains("ff_itl_ms_p50{class=\"interactive\"} 3"));
        assert!(text.contains("ff_itl_ms_mean{class=\"batch\"} 9"));
        assert!(text
            .contains("ff_queue_delay_ms_p50{class=\"batch\"} 30"));
        // valid exposition format: one HELP/TYPE block per metric name
        // even when both classes have samples
        let helps: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("# HELP"))
            .collect();
        let mut dedup = helps.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(helps.len(), dedup.len(), "duplicate HELP lines");
    }

    #[test]
    fn cluster_and_failover_series() {
        let m = Metrics::new();
        // plain serve exposition carries neither block
        let text = m.export();
        assert!(!text.contains("ff_cluster_"));
        assert!(!text.contains("ff_failover_"));
        m.record_failover(3, 1);
        m.ensure_cluster_workers(2);
        m.record_cluster_dispatch(ClusterRoute::Affine);
        m.record_cluster_dispatch(ClusterRoute::Affine);
        m.record_cluster_dispatch(ClusterRoute::Fallback);
        m.record_cluster_shed(429);
        m.record_cluster_shed(503);
        m.record_cluster_shed(503);
        m.record_cluster_quota_reject();
        m.record_cluster_backplane_error();
        m.record_cluster_retry();
        m.set_cluster_worker(0, true, 4);
        m.set_cluster_worker(1, false, 0);
        assert_eq!(m.failover_counts(), (3, 1));
        assert_eq!(m.cluster_dispatches(), (2, 1, 0));
        assert_eq!(m.cluster_sheds(), (1, 2));
        let text = m.export();
        assert!(text.contains("ff_failover_rerouted_total 3"));
        assert!(text.contains("ff_failover_failed_total 1"));
        assert!(text
            .contains("ff_cluster_dispatch_total{route=\"affine\"} 2"));
        assert!(text
            .contains("ff_cluster_dispatch_total{route=\"fallback\"} 1"));
        assert!(text
            .contains("ff_cluster_dispatch_total{route=\"random\"} 0"));
        assert!(
            text.contains("ff_cluster_affinity_hit_rate 0.66"),
            "2/3 affine: {text}"
        );
        assert!(text.contains("ff_cluster_sheds_total{code=\"429\"} 1"));
        assert!(text.contains("ff_cluster_sheds_total{code=\"503\"} 2"));
        assert!(text.contains("ff_cluster_quota_rejects_total 1"));
        assert!(text.contains("ff_cluster_backplane_errors_total 1"));
        assert!(text.contains("ff_cluster_retries_total 1"));
        assert!(text.contains("ff_cluster_worker_healthy{worker=\"0\"} 1"));
        assert!(text.contains("ff_cluster_worker_healthy{worker=\"1\"} 0"));
        assert!(text.contains("ff_cluster_worker_inflight{worker=\"0\"} 4"));
        // still a valid exposition: no duplicate HELP lines
        let helps: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("# HELP"))
            .collect();
        let mut dedup = helps.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(helps.len(), dedup.len(), "duplicate HELP lines");
    }

    #[test]
    fn thread_safety() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let hs: Vec<_> = (0..8)
            .map(|i| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for j in 0..100 {
                        m.record_ttft((i * 100 + j) as f64);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        let g = m.export();
        assert!(g.contains("ff_ttft_ms_mean"));
    }
}
