//! Continuous batching: batched decode and mixed prefill-chunk/decode
//! steps through one shared forward pass.
//!
//! The sequential engine carries exactly one sequence per forward
//! pass, so B decoding requests re-stream every weight panel B times
//! per token. [`Engine::step_batch`] folds B decode rows — plus at
//! most one prefill chunk from another request — into **one** pass
//! over the layer weights: per layer, every row's executable is
//! resolved exactly as the sequential path would resolve it, the row
//! set is handed to the backend in one batched dispatch
//! ([`crate::runtime::Runtime::run_layer_batch`]), and each row's
//! fresh KV rows scatter into that sequence's own cache through a
//! disjoint [`crate::kvcache::StepKv`] view.
//!
//! **Bit-identity.** A batched step produces logits and KV
//! bit-identical to running the same sequences one at a time: every
//! kernel behind the fused CPU path is row-independent with an
//! unchanged per-element accumulation order, and the sequential
//! fallback (PJRT, the reference oracle, split-pipeline chunks) *is*
//! the one-at-a-time dispatch. `tests/backend_conformance.rs` pins
//! this against [`crate::runtime::CpuBackend::reference`]. Block-
//! sparse attention rows keep the guarantee for free: the chunk plan
//! carries the resolved `a{pct}` executable name, and the fused CPU
//! path computes each row's block-selection plan sequentially before
//! its row-parallel loop — identical to the sequential dispatch.
//!
//! [`DecodeBatch`] is the scheduler-facing lockstep container:
//! sequences join as their prefill finishes, leave as they hit EOS or
//! their token budget, and every [`DecodeBatch::step`] folds the
//! staged members (split into passes of at most `max_batch` rows)
//! into shared forward passes.

use std::time::Instant;

use anyhow::Result;

use super::session::ChunkPlan;
use super::{Engine, PrefillSession, SparsityConfig};
use crate::kvcache::{SeqKvCache, StepKv};
use crate::runtime::StepRow;

/// One decode row of a mixed step: feed `token` at `pos` into the
/// sequence behind `cache`, under that request's `cfg`.
pub struct DecodeSlot<'a> {
    /// The token fed at this step (the previous step's sampled token).
    pub token: i32,
    /// Absolute position the token is fed at.
    pub pos: usize,
    /// The sequence's KV cache (fresh rows scatter into it).
    pub cache: &'a mut SeqKvCache,
    /// The request's sparsity configuration.
    pub cfg: &'a SparsityConfig,
}

/// What one [`Engine::step_batch`] call produced.
pub struct StepBatchResult {
    /// Next-token logits per decode slot, in slot order.
    pub logits: Vec<Vec<f32>>,
    /// Prompt tokens the prefill chunk consumed (0 when none rode
    /// along).
    pub chunk_tokens: usize,
}

impl Engine {
    /// Run one continuous-batching step: every decode slot plus at
    /// most one prefill chunk through a single shared pass over the
    /// layer weights.
    ///
    /// The chunk is the next scheduling unit of `prefill` (one full
    /// block, or one ragged-tail token); a unit that needs the split
    /// sequential pipeline (ablation expert sources, first-block
    /// static capture) runs through [`PrefillSession::step`] instead,
    /// and only the decode rows share the batched pass. Each decode
    /// slot's per-layer executables are exactly the ones
    /// [`Engine::decode_step`] would dispatch, so a batch of size one
    /// is the sequential path under a different entry point — and any
    /// batch is bit-identical to it.
    pub fn step_batch(&self, mut prefill: Option<&mut PrefillSession>,
                      decodes: &mut [DecodeSlot<'_>])
                      -> Result<StepBatchResult> {
        let n_layers = self.n_layers;

        // ---- plan the prefill chunk -------------------------------
        let mut chunk_tokens = 0usize;
        let chunk_plan: Option<ChunkPlan> = match prefill.as_deref_mut() {
            Some(session) => match session.plan_batch_step()? {
                Some(plan) => Some(plan),
                None => {
                    // split pipeline required: run the unit through
                    // the sequential session step; the decode rows
                    // still share one batched pass below.
                    chunk_tokens = session.step()?;
                    None
                }
            },
            None => None,
        };

        // ---- plan the rows (chunk first, then decode slots) -------
        let chunk_rows = chunk_plan.is_some() as usize;
        let n_rows = chunk_rows + decodes.len();
        let mut xs: Vec<Vec<f32>> = Vec::with_capacity(n_rows);
        let mut ts: Vec<usize> = Vec::with_capacity(n_rows);
        let mut poss: Vec<usize> = Vec::with_capacity(n_rows);
        let mut exes: Vec<Vec<String>> = Vec::with_capacity(n_rows);
        if let Some(plan) = &chunk_plan {
            xs.push(plan.x.clone());
            ts.push(plan.t);
            poss.push(plan.pos);
            exes.push(plan.exes.clone());
        }
        for slot in decodes.iter_mut() {
            self.ensure_bucket(slot.cache, slot.pos + 1)?;
            let layer_ks = self.layer_ks(slot.cfg)?;
            let decode_ks = self.decode_ks_for(&layer_ks);
            let sparse = !slot.cfg.is_dense() && slot.cfg.sparse_decode;
            let bucket = slot.cache.bucket;
            exes.push(
                (0..n_layers)
                    .map(|l| {
                        self.token_exe(slot.cfg, sparse, decode_ks[l],
                                       bucket)
                    })
                    .collect(),
            );
            ts.push(1);
            poss.push(slot.pos);
            xs.push(self.embed(&[slot.token])?);
        }
        if n_rows == 0 {
            return Ok(StepBatchResult {
                logits: Vec::new(),
                chunk_tokens,
            });
        }

        // ---- the shared layer loop --------------------------------
        let t_layers = Instant::now();
        {
            let mut caches: Vec<&mut SeqKvCache> =
                Vec::with_capacity(n_rows);
            if chunk_rows == 1 {
                let session = prefill
                    .as_deref_mut()
                    .expect("chunk plan without a session");
                caches.push(&mut session.cache);
            }
            for slot in decodes.iter_mut() {
                caches.push(&mut *slot.cache);
            }
            let mut kv = StepKv::new(caches);
            for l in 0..n_layers {
                let rows: Vec<StepRow> = (0..n_rows)
                    .map(|i| {
                        let (k_cache, v_cache) = kv.layer(i, l);
                        StepRow {
                            exe: exes[i][l].as_str(),
                            x: &xs[i],
                            t: ts[i],
                            pos: poss[i],
                            k_cache,
                            v_cache,
                            s: kv.bucket(i),
                        }
                    })
                    .collect();
                let outs = self.rt.run_layer_batch(l, &rows)?;
                drop(rows);
                for (i, out) in outs.into_iter().enumerate() {
                    kv.append(i, l, &out.k_new, &out.v_new, ts[i])?;
                    xs[i] = out.y;
                }
            }
            // Decode rows advance their write cursor here; the
            // chunk's cursor advances in `complete_batch_step` (with
            // the rest of the session bookkeeping).
            for i in chunk_rows..n_rows {
                kv.advance(i, 1);
            }
        }
        let layers_dt = t_layers.elapsed();

        // ---- fold results back ------------------------------------
        if let Some(plan) = &chunk_plan {
            let session = prefill
                .as_deref_mut()
                .expect("chunk plan without a session");
            let x_out = std::mem::take(&mut xs[0]);
            // `layers_dt` covers the whole shared pass; it is
            // attributed to the step that scheduled it.
            session.complete_batch_step(plan, x_out, layers_dt);
            chunk_tokens = plan.t;
        }
        let mut logits = Vec::with_capacity(decodes.len());
        for i in chunk_rows..n_rows {
            logits.push(self.lm_head(&xs[i], 1)?);
        }
        Ok(StepBatchResult {
            logits,
            chunk_tokens,
        })
    }
}

/// One member sequence of a [`DecodeBatch`].
struct DecodeSeq {
    cache: SeqKvCache,
    pos: usize,
    logits: Vec<f32>,
    cfg: SparsityConfig,
    /// Token staged by [`DecodeBatch::feed`], consumed by the next
    /// [`DecodeBatch::step`].
    pending: Option<i32>,
}

/// One forward pass within a [`DecodeBatch::step`].
#[derive(Debug, Clone)]
pub struct StepPass {
    /// Sequence rows the pass carried (decode rows plus the prefill
    /// chunk when it rode this pass) — the samples behind the
    /// `ff_batch_occupancy` metric.
    pub rows: usize,
    /// Whether the prefill chunk rode this pass.
    pub chunk: bool,
    /// Wall-clock of the pass in milliseconds.
    pub ms: f64,
}

/// One failed pass within a [`DecodeBatch::step`]: only the rows of
/// *this* pass are affected — members advanced by earlier passes (and
/// stepped by later ones) stay healthy.
#[derive(Debug, Clone)]
pub struct StepFailure {
    /// Member ids that were rows of the failed pass.
    pub members: Vec<usize>,
    /// Whether the prefill chunk was part of the failed pass.
    pub chunk: bool,
    /// The engine error, stringified.
    pub error: String,
}

/// Occupancy and progress accounting of one [`DecodeBatch::step`].
#[derive(Debug, Default, Clone)]
pub struct StepStats {
    /// Forward passes executed (successful or failed).
    pub steps: usize,
    /// Sequence rows folded across those passes (decode rows plus the
    /// prefill chunk when one rode along).
    pub rows: usize,
    /// Per-pass occupancy and timing, in execution order.
    pub passes: Vec<StepPass>,
    /// Passes that failed, with exactly the member rows they carried.
    pub failures: Vec<StepFailure>,
}

/// Lockstep multi-session decode: the scheduler-facing container
/// behind continuous batching.
///
/// Sequences [`join`](DecodeBatch::join) as their prefill finishes
/// (bringing their filled KV cache and last-position logits) and
/// [`leave`](DecodeBatch::leave) as they hit EOS or their token
/// budget. Sampling stays with the caller: it reads a member's
/// [`logits`](DecodeBatch::logits), picks a token, and
/// [`feed`](DecodeBatch::feed)s it back; one
/// [`step`](DecodeBatch::step) then advances every staged member —
/// plus at most one prefill chunk — through shared forward passes of
/// at most `max_batch` rows each.
pub struct DecodeBatch {
    engine: Engine,
    /// Slot map: `join` reuses freed slots so member ids stay stable
    /// for the lifetime of a sequence.
    seqs: Vec<Option<DecodeSeq>>,
}

impl DecodeBatch {
    /// Empty batch bound to `engine`.
    pub fn new(engine: Engine) -> Self {
        DecodeBatch {
            engine,
            seqs: Vec::new(),
        }
    }

    /// Number of member sequences currently decoding.
    pub fn len(&self) -> usize {
        self.seqs.iter().filter(|s| s.is_some()).count()
    }

    /// Whether no sequence is currently decoding.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Members with a staged token (rows the next [`DecodeBatch::step`]
    /// will advance).
    pub fn staged(&self) -> usize {
        self.seqs
            .iter()
            .filter(|s| {
                s.as_ref().is_some_and(|s| s.pending.is_some())
            })
            .count()
    }

    /// Join a sequence whose prefill just finished: its filled KV
    /// `cache`, next position (= prompt length), last-position
    /// `logits` and configuration. Returns the member id used with
    /// every other method.
    pub fn join(&mut self, cache: SeqKvCache, pos: usize,
                logits: Vec<f32>, cfg: SparsityConfig) -> usize {
        let seq = DecodeSeq {
            cache,
            pos,
            logits,
            cfg,
            pending: None,
        };
        match self.seqs.iter_mut().position(|s| s.is_none()) {
            Some(i) => {
                self.seqs[i] = Some(seq);
                i
            }
            None => {
                self.seqs.push(Some(seq));
                self.seqs.len() - 1
            }
        }
    }

    /// Remove member `id` (finished, cancelled or failed), returning
    /// its KV cache to the caller.
    pub fn leave(&mut self, id: usize) -> SeqKvCache {
        let seq =
            self.seqs[id].take().expect("leave of unknown decode seq");
        while matches!(self.seqs.last(), Some(None)) {
            self.seqs.pop();
        }
        seq.cache
    }

    /// Member `id`'s current next-token logits.
    pub fn logits(&self, id: usize) -> &[f32] {
        &self.seqs[id].as_ref().expect("unknown decode seq").logits
    }

    /// Stage the sampled token for member `id`; the next
    /// [`DecodeBatch::step`] feeds it and refreshes the member's
    /// logits.
    pub fn feed(&mut self, id: usize, token: i32) {
        let s = self.seqs[id].as_mut().expect("unknown decode seq");
        debug_assert!(s.pending.is_none(), "feed before previous step");
        s.pending = Some(token);
    }

    /// Advance every staged member by one token — and `prefill` by one
    /// scheduling unit, riding the first pass — through shared forward
    /// passes of at most `max_batch` rows each. Members without a
    /// staged token are untouched.
    ///
    /// A pass that errors fails **only its own rows**: they are
    /// reported in [`StepStats::failures`] (their staged tokens
    /// consumed, their logits left stale) so the caller can fail
    /// exactly the affected requests; every other pass of the step
    /// still runs and its members stay healthy.
    pub fn step(&mut self, mut prefill: Option<&mut PrefillSession>,
                max_batch: usize) -> StepStats {
        let max_batch = max_batch.max(1);
        let staged: Vec<usize> = self
            .seqs
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                s.as_ref().is_some_and(|s| s.pending.is_some())
            })
            .map(|(i, _)| i)
            .collect();
        let mut stats = StepStats::default();
        let mut start = 0usize;
        while start < staged.len() || prefill.is_some() {
            let chunk = prefill.take();
            let had_chunk = chunk.is_some();
            let room = max_batch.saturating_sub(had_chunk as usize);
            let group =
                &staged[start..(start + room).min(staged.len())];
            start += group.len();
            // Take the members out of the slot map so the batch can
            // hold one `&mut` cache per row.
            let mut taken: Vec<(usize, DecodeSeq)> = group
                .iter()
                .map(|&id| {
                    (id, self.seqs[id].take().expect("staged member"))
                })
                .collect();
            let occupancy = taken.len() + had_chunk as usize;
            let t0 = Instant::now();
            let res = {
                let mut slots: Vec<DecodeSlot<'_>> = taken
                    .iter_mut()
                    .map(|(_, s)| DecodeSlot {
                        token: s.pending.take().expect("staged token"),
                        pos: s.pos,
                        cache: &mut s.cache,
                        cfg: &s.cfg,
                    })
                    .collect();
                self.engine.step_batch(chunk, &mut slots)
            };
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            stats.steps += 1;
            stats.rows += occupancy;
            match res {
                Ok(r) => {
                    for ((_, s), lg) in taken.iter_mut().zip(r.logits) {
                        s.logits = lg;
                        s.pos += 1;
                    }
                    stats.passes.push(StepPass {
                        rows: occupancy,
                        chunk: had_chunk,
                        ms,
                    });
                }
                Err(e) => {
                    stats.failures.push(StepFailure {
                        members: taken.iter().map(|(id, _)| *id).collect(),
                        chunk: had_chunk,
                        error: e.to_string(),
                    });
                }
            }
            for (id, s) in taken {
                self.seqs[id] = Some(s);
            }
        }
        stats
    }
}
