//! Generation + teacher-forced scoring on top of the block engine.

use anyhow::Result;

use super::{argmax, log_softmax, Engine, PrefillTiming, SparsityConfig};
use crate::tokenizer::EOS;

/// Greedy generation outcome.
#[derive(Debug, Clone)]
pub struct GenerateResult {
    /// Generated token ids (EOS excluded).
    pub tokens: Vec<i32>,
    /// Decoded text of `tokens`.
    pub text: String,
    /// Time to first token (prefill wall-clock) in milliseconds.
    pub ttft_ms: f64,
    /// Mean time per output token in milliseconds.
    pub tpot_ms: f64,
    /// Prefill timing breakdown.
    pub prefill: PrefillTiming,
}

/// Teacher-forced continuation scoring outcome.
#[derive(Debug, Clone)]
pub struct ScoreResult {
    /// Mean per-token log-probability of the reference continuation.
    pub mean_logprob: f64,
    /// exp(mean_logprob) ∈ (0, 1]: per-token probability score.
    pub likelihood: f64,
    /// Length of the scored continuation in tokens.
    pub n_tokens: usize,
    /// Prefill timing breakdown.
    pub prefill: PrefillTiming,
}

impl Engine {
    /// Greedy-decode up to `max_tokens` after prefilling `prompt`.
    pub fn generate(&self, prompt: &[i32], max_tokens: usize,
                    cfg: &SparsityConfig) -> Result<GenerateResult> {
        let t0 = std::time::Instant::now();
        let mut pre = self.prefill(prompt, cfg)?;
        let ttft_ms = t0.elapsed().as_secs_f64() * 1e3; // first logits ready
        // decode continues from the cache length — under token pruning
        // the KV holds only the surviving tokens at compacted positions
        let mut pos = pre.cache.len;
        let mut logits = pre.last_logits.clone();
        let mut out = Vec::new();
        let t1 = std::time::Instant::now();
        for _ in 0..max_tokens {
            let tok = argmax(&logits) as i32;
            if tok == EOS {
                break;
            }
            out.push(tok);
            logits = self.decode_step(tok, pos, &mut pre.cache, cfg)?;
            pos += 1;
        }
        let tpot_ms = if out.is_empty() {
            0.0
        } else {
            t1.elapsed().as_secs_f64() * 1e3 / out.len() as f64
        };
        let tok = crate::tokenizer::Tokenizer::new(
            self.manifest().model.vocab,
        );
        Ok(GenerateResult {
            text: tok.decode(&out),
            tokens: out,
            ttft_ms,
            tpot_ms,
            prefill: pre.timing,
        })
    }

    /// Teacher-forced log-likelihood of `answer` given `prompt` — the
    /// primary longbench-sim metric (smooth in sparsity-induced error;
    /// see trace::longbench).
    pub fn score_continuation(&self, prompt: &[i32], answer: &[i32],
                              cfg: &SparsityConfig) -> Result<ScoreResult> {
        anyhow::ensure!(!answer.is_empty(), "empty answer");
        let mut pre = self.prefill(prompt, cfg)?;
        // compacted-position decode, as in `generate`
        let mut pos = pre.cache.len;
        let mut logits = pre.last_logits.clone();
        let mut total_lp = 0.0f64;
        for (i, &tok) in answer.iter().enumerate() {
            let lp = log_softmax(&logits);
            total_lp += lp[tok as usize] as f64;
            if i + 1 < answer.len() {
                logits = self.decode_step(tok, pos, &mut pre.cache, cfg)?;
                pos += 1;
            }
        }
        let mean = total_lp / answer.len() as f64;
        Ok(ScoreResult {
            mean_logprob: mean,
            likelihood: mean.exp(),
            n_tokens: answer.len(),
            prefill: pre.timing,
        })
    }
}
