//! Block-wise prefill + decode engine: the FastForward fast path.
//!
//! Prompts are processed in 128-token blocks (paper §3.1). Per block and
//! per layer the engine dispatches one of the AOT executables:
//!
//! * dense blocks (first/last, or density-1 layers) → `layer_dense_*`
//! * sparse blocks, trained predictor + compensator → the fused
//!   `layer_sparse_k{K}_*` (predictor → top-K → gathered FFN → comp, all
//!   inside one executable — one dispatch per layer)
//! * ablation variants (oracle / first-block-static / no-compensator) →
//!   the split pipeline `layer_attn` → scores → host top-K →
//!   `ffn_sparse_ext_k{K}`.
//!
//! The ragged prompt tail (len % 128) runs through T=1 decode-shaped
//! executables, which keeps numerics exact without padding the KV cache
//! with garbage positions.

mod batch;
mod generate;
mod session;

pub use batch::{DecodeBatch, DecodeSlot, StepBatchResult, StepFailure,
                StepPass, StepStats};
pub use generate::{GenerateResult, ScoreResult};
pub use session::PrefillSession;

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::kvcache::SeqKvCache;
use crate::manifest::Manifest;
use crate::runtime::{Input, Runtime};
use crate::sparsity::masks::{top_k_indices, ExpertSource};
use crate::sparsity::schedule::{layerwise_schedule, quantize_densities};

/// Full sparsity configuration for a request (paper §3 + ablations).
#[derive(Debug, Clone)]
pub struct SparsityConfig {
    /// None = dense baseline; Some(s) = target sparsity (0.3/0.4/0.5).
    pub sparsity: Option<f64>,
    /// Layerwise schedule (Algorithm 1) vs uniform allocation (Tab. 4).
    pub layerwise: bool,
    /// Keep the first block dense (attention sinks, §3.4 / Tab. 5).
    pub dense_first: bool,
    /// Keep the last block dense (QA answer locality, §3.4 / Tab. 5).
    pub dense_last: bool,
    /// Apply the error compensation network (§3.3 / Tab. 6).
    pub compensator: bool,
    /// Expert index source (Tab. 7).
    pub source: ExpertSource,
    /// Apply FFN sparsity during decode as well (Tab. 3).
    pub sparse_decode: bool,
    /// Block-sparse attention for full prefill blocks: `None` = dense
    /// attention (the original path, untouched); `Some(a)` = drop
    /// fraction `a` of the *optional* causal key blocks per query
    /// block per head, always keeping the sink + local band
    /// ([`crate::sparsity::attn`]). `Some(0.0)` routes through the
    /// sparse machinery at full coverage — bit-identical to dense.
    /// Quantized onto the manifest's compiled `attn_grid`; orthogonal
    /// to (and composable with) the FFN `sparsity` knob. T=1 steps
    /// (ragged tail, decode) always run dense attention.
    pub attn_sparsity: Option<f64>,
    /// Speculative prefill: `None` (or `Some(1.0)`) = prefill every
    /// prompt token (the original path, untouched); `Some(r)` with
    /// `r < 1.0` = score every prompt token with the low-rank predictor
    /// once, keep the top `ceil(r · n)` tokens (always including the
    /// sink + local bands, [`crate::sparsity::tokens`]), and prefill
    /// only the survivors at consecutive compacted positions. The KV
    /// cache then holds `ceil(r · n)` rows instead of `n` — context
    /// reduction decoupled from the FFN/attention sparsity axes, and
    /// composable with both.
    pub token_keep_ratio: Option<f64>,
}

impl SparsityConfig {
    /// The dense baseline: no sparsity, no overhead networks.
    pub fn dense() -> Self {
        SparsityConfig {
            sparsity: None,
            layerwise: false,
            dense_first: false,
            dense_last: false,
            compensator: false,
            source: ExpertSource::Trained,
            sparse_decode: false,
            attn_sparsity: None,
            token_keep_ratio: None,
        }
    }

    /// The paper's full method at a given sparsity.
    ///
    /// ```
    /// use fastforward::engine::SparsityConfig;
    ///
    /// let cfg = SparsityConfig::fastforward(0.5);
    /// assert_eq!(cfg.sparsity, Some(0.5));
    /// assert!(cfg.layerwise && cfg.dense_first && cfg.dense_last);
    /// assert!(cfg.compensator && !cfg.sparse_decode);
    /// assert!(!cfg.is_dense());
    /// // prefill numerics are fingerprinted so the prefix cache never
    /// // mixes KV across configurations
    /// assert_ne!(cfg.prefill_fingerprint(),
    ///            SparsityConfig::dense().prefill_fingerprint());
    /// ```
    pub fn fastforward(sparsity: f64) -> Self {
        SparsityConfig {
            sparsity: Some(sparsity),
            layerwise: true,
            dense_first: true,
            dense_last: true,
            compensator: true,
            source: ExpertSource::Trained,
            sparse_decode: false,
            attn_sparsity: None,
            token_keep_ratio: None,
        }
    }

    /// Whether the FFN path is the dense baseline (no FFN sparsity).
    /// Deliberately ignores `attn_sparsity`: attention sparsity is an
    /// orthogonal axis that rides on the dense-FFN executables when no
    /// FFN sparsity is requested.
    pub fn is_dense(&self) -> bool {
        self.sparsity.is_none()
    }

    /// Whether prefill KV computed under this configuration is
    /// position-generic enough for the prefix cache.
    ///
    /// The one exception is the GRIFFIN-style `FirstBlockStatic` ablation:
    /// it captures expert indices on the prompt's first block during
    /// prefill, and a session that adopts cached blocks would skip that
    /// capture — so both adoption and insertion are disabled for it.
    pub fn prefix_cacheable(&self) -> bool {
        self.is_dense() || self.source != ExpertSource::FirstBlockStatic
    }

    /// Stable 64-bit fingerprint of every field that influences prefill
    /// numerics. Combined with the runtime's model + backend
    /// fingerprint in [`Engine::prefix_seed`], it seeds the
    /// prefix-cache hash chain so KV rows are only ever adopted by
    /// sessions running the *same* configuration (sparse KV differs
    /// numerically from dense KV, and CPU-interpreter KV differs from
    /// PJRT KV). `sparse_decode` is
    /// deliberately excluded: it only affects decode steps, never the
    /// full blocks the cache stores, so including it would pointlessly
    /// fragment the cache across otherwise-identical configurations.
    pub fn prefill_fingerprint(&self) -> u64 {
        fn mix(h: u64, v: u64) -> u64 {
            let x = (h ^ v).wrapping_mul(0x100000001b3);
            x ^ (x >> 31)
        }
        let mut h = 0xFA57_F0A4_D15C_0DE5u64;
        h = mix(h, self.sparsity.map(|s| s.to_bits()).unwrap_or(u64::MAX));
        h = mix(
            h,
            (self.layerwise as u64)
                | (self.dense_first as u64) << 1
                | (self.dense_last as u64) << 2
                | (self.compensator as u64) << 3,
        );
        h = mix(
            h,
            match self.source {
                ExpertSource::Trained => 1,
                ExpertSource::Oracle => 2,
                ExpertSource::FirstBlockStatic => 3,
                ExpertSource::Cats => 4,
            },
        );
        // attention-sparse KV differs numerically from dense KV at
        // every layer past the first — the prefix cache must never
        // adopt rows across attention configurations
        h = mix(
            h,
            self.attn_sparsity.map(|a| a.to_bits()).unwrap_or(u64::MAX),
        );
        // pruned-prompt KV holds different tokens at different
        // positions than the full prompt's; `Some(1.0)` is the
        // identity selection and deliberately shares the unpruned
        // fingerprint (the KV is bit-identical by construction)
        h = mix(
            h,
            self.token_keep_ratio
                .filter(|&r| r < 1.0)
                .map(|r| r.to_bits())
                .unwrap_or(u64::MAX),
        );
        h
    }
}

/// Timing breakdown of one prefill (drives Fig. 1 / Fig. 2).
#[derive(Debug, Clone, Default)]
pub struct PrefillTiming {
    /// Wall-clock of the whole prefill.
    pub total: Duration,
    /// Time in token-embedding dispatches.
    pub embed: Duration,
    /// Time in transformer-layer dispatches.
    pub layers: Duration,
    /// Time in the final LM-head dispatch.
    pub lm_head: Duration,
    /// Full 128-token blocks *executed* by this session. Blocks adopted
    /// from the prefix cache are excluded — this is the engine's
    /// block-execution counter, the ground truth that a prefix hit
    /// actually skipped compute.
    pub blocks: usize,
    /// Executed blocks that ran the dense path.
    pub dense_blocks: usize,
    /// Ragged-tail tokens processed through T=1 steps.
    pub tail_tokens: usize,
    /// Blocks whose KV was adopted from the prefix cache (not executed).
    pub adopted_blocks: usize,
    /// Time in the speculative-prefill scoring pass (zero when no
    /// token pruning was requested).
    pub score: Duration,
    /// Prompt tokens dropped by speculative token pruning before the
    /// main prefill (zero on the unpruned path).
    pub pruned_tokens: usize,
}

/// Result of prefilling one prompt.
pub struct PrefillResult {
    /// The filled KV cache (`len` == the number of prefilled tokens:
    /// the prompt length, or the keep-set size under token pruning).
    pub cache: SeqKvCache,
    /// Hidden state of the final prompt position, [d_model].
    pub last_hidden: Vec<f32>,
    /// Logits at the final prompt position, [vocab].
    pub last_logits: Vec<f32>,
    /// Timing and block-count breakdown.
    pub timing: PrefillTiming,
    /// Speculative-prefill keep map: the ascending original prompt
    /// indices of the surviving tokens (`None` when the prompt was
    /// prefilled whole). `cache` row `i` holds the KV of original
    /// prompt token `keep_map[i]`, computed at compacted position `i`.
    pub keep_map: Option<Vec<u32>>,
}

/// Block-wise prefill + decode engine bound to one [`Runtime`].
///
/// `Engine` is deliberately cheap to clone (it shares the
/// `Arc<Runtime>`) but **not** `Send`: the runtime's backend holds
/// per-replica mutable caches, so every executor-pool replica
/// constructs its own engine on its own thread from the same (shared,
/// `Arc`'d) manifest + weight store. The `Arc` handle is what lets one
/// replica's sessions, decode batches and sampling plumbing all point
/// at a single runtime without reference-count gymnastics.
#[derive(Clone)]
pub struct Engine {
    /// The runtime executing the manifest's executables.
    pub rt: Arc<Runtime>,
    block: usize,
    d: usize,
    n_layers: usize,
}

impl Engine {
    /// Build an engine over a loaded runtime.
    pub fn new(rt: Arc<Runtime>) -> Self {
        let m = &rt.manifest.model;
        Engine {
            block: m.block,
            d: m.d_model,
            n_layers: m.n_layers,
            rt,
        }
    }

    /// Build a fully self-contained engine: synthetic manifest, seeded
    /// deterministic weights, pure-Rust CPU backend (fast tiled
    /// kernels; thread count from `FF_CPU_THREADS` / available
    /// parallelism). No artifacts, no `pjrt` feature — this is what the
    /// always-on numeric test tier and `--backend cpu` serving run on.
    pub fn synthetic_cpu(
        spec: &crate::manifest::SyntheticSpec,
    ) -> Result<Engine> {
        Self::synthetic_cpu_with(
            spec,
            crate::runtime::CpuOptions::default(),
        )
    }

    /// [`Engine::synthetic_cpu`] with explicit CPU backend options —
    /// how the conformance suite builds reference (sequential oracle)
    /// and fast (`threads ∈ {1, 4, …}`, scalar/SIMD kernel tier)
    /// engines over the *same* seeded weights. The spec's
    /// `weight_precision` selects the storage mode
    /// ([`crate::weights::WeightStore::seeded_with`]): f32, bf16 (raw
    /// u16 panels, widened in-register) or int8 (codes +
    /// per-column-tile scales, dequantized in-register) — exactly one
    /// representation stays resident per store.
    pub fn synthetic_cpu_with(
        spec: &crate::manifest::SyntheticSpec,
        opts: crate::runtime::CpuOptions,
    ) -> Result<Engine> {
        let manifest = Arc::new(Manifest::synthetic(spec));
        let weights = Arc::new(crate::weights::WeightStore::seeded_with(
            &manifest,
            spec.seed,
            spec.weight_precision,
        ));
        Ok(Engine::new(Arc::new(Runtime::cpu_with_options(
            manifest, weights, opts,
        )?)))
    }

    /// The artifact manifest this engine dispatches against.
    pub fn manifest(&self) -> &Manifest {
        &self.rt.manifest
    }

    /// Seed for the prefix-cache hash chain: the sparsity
    /// configuration's prefill fingerprint mixed with the runtime's
    /// model + backend fingerprint. Two sessions may share cached KV
    /// only when *all three* match — config, model, and backend.
    pub fn prefix_seed(&self, cfg: &SparsityConfig) -> u64 {
        cfg.prefill_fingerprint() ^ self.rt.numeric_fingerprint()
    }

    /// Prefill block size in tokens (paper §3.1: 128).
    pub fn block(&self) -> usize {
        self.block
    }

    /// Per-layer FFN widths for sparse blocks under `cfg`; d_ffn = dense.
    pub fn layer_ks(&self, cfg: &SparsityConfig) -> Result<Vec<usize>> {
        let m = &self.rt.manifest;
        let Some(sp) = cfg.sparsity else {
            return Ok(vec![m.model.d_ffn; self.n_layers]);
        };
        if cfg.layerwise {
            Ok(m.budget(sp)?.layer_k.clone())
        } else {
            // uniform allocation at the same budget, same quantization
            let dens = layerwise_schedule(
                &vec![1.0; self.n_layers],
                1.0 - sp,
            );
            Ok(quantize_densities(&dens, m.model.d_ffn, m.model.ftile))
        }
    }

    /// The `a{pct}_` name segment for an attention drop level (empty
    /// for the dense attention path).
    fn a_seg(a: Option<usize>) -> String {
        a.map(|p| format!("a{p}_")).unwrap_or_default()
    }

    fn exe_name_dense(&self, a: Option<usize>, t: usize, s: usize)
                      -> String {
        format!("layer_dense_{}t{t}_s{s}", Self::a_seg(a))
    }

    fn exe_name_sparse(&self, a: Option<usize>, k: usize, t: usize,
                       s: usize) -> String {
        format!("layer_sparse_{}k{k}_t{t}_s{s}", Self::a_seg(a))
    }

    /// Resolve `cfg.attn_sparsity` onto the manifest's compiled
    /// attention-drop grid (percent levels, nearest wins, ties toward
    /// the lower level). `Ok(None)` = dense attention. Fails fast when
    /// attention sparsity is requested against a manifest that ships
    /// no attention-sparse executables — silently running dense would
    /// misreport every speedup measured on top.
    pub(crate) fn attn_pct(&self, cfg: &SparsityConfig)
                           -> Result<Option<usize>> {
        let Some(a) = cfg.attn_sparsity else { return Ok(None) };
        anyhow::ensure!(
            (0.0..=1.0).contains(&a),
            "attn sparsity {a} outside [0, 1]"
        );
        let grid = &self.rt.manifest.attn_grid;
        anyhow::ensure!(
            !grid.is_empty(),
            "attention sparsity requested but the manifest ships no \
             attention-sparse executables (empty attn_grid)"
        );
        let target = (a * 100.0).round() as i64;
        Ok(grid
            .iter()
            .copied()
            .min_by_key(|&g| ((g as i64 - target).abs(), g)))
    }

    /// Resolve `cfg.token_keep_ratio`. `Ok(None)` = no pruning — both
    /// the unset case and `Some(1.0)`, whose identity selection is
    /// skipped outright so the unpruned path stays bit-identical by
    /// construction. Fails fast when pruning is requested against a
    /// manifest that ships no predictor executable (the scorer) —
    /// silently prefilling the whole prompt would misreport every
    /// speedup measured on top.
    pub(crate) fn token_keep(&self, cfg: &SparsityConfig)
                             -> Result<Option<f64>> {
        let Some(r) = cfg.token_keep_ratio else { return Ok(None) };
        anyhow::ensure!(
            (0.0..=1.0).contains(&r),
            "token keep ratio {r} outside [0, 1]"
        );
        if r >= 1.0 {
            return Ok(None);
        }
        let scorer = format!("predictor_t{}", self.block);
        anyhow::ensure!(
            self.rt.manifest.has_executable(&scorer),
            "token pruning requested but the manifest ships no \
             predictor executable ({scorer}) to score tokens with"
        );
        Ok(Some(r))
    }

    /// The speculative-prefill scoring pass: one cheap importance
    /// estimate per prompt token, computed *before* the main prefill.
    ///
    /// Each `block`-sized chunk of the prompt is embedded and fed to
    /// the layer-0 low-rank predictor (`predictor_t{block}` — the PR 4
    /// expert scorer repurposed over pooled embeddings, no attention
    /// and no KV involved); a token's importance is the mean absolute
    /// predicted neuron score across the FFN axis — tokens that excite
    /// the FFN strongly are the ones worth prefilling. The ragged tail
    /// chunk is padded with token 0 to the full block shape (only
    /// `predictor_t{block}` is compiled) and the padded positions'
    /// scores are discarded. The host reduction is sequential, so
    /// scores — and therefore the keep-set — are invariant under
    /// thread count and batch shape.
    pub(crate) fn token_scores(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let d_ffn = self.rt.manifest.model.d_ffn;
        let t = self.block;
        let mut scores = Vec::with_capacity(tokens.len());
        for chunk in tokens.chunks(t) {
            let h = if chunk.len() == t {
                self.embed(chunk)?
            } else {
                let mut padded = chunk.to_vec();
                padded.resize(t, 0);
                self.embed(&padded)?
            };
            let out = self.rt.run(
                &format!("predictor_t{t}"),
                0,
                &[("h", Input::F32(&h, vec![t, self.d]))],
            )?;
            let pred = out.into_iter().next().unwrap().data;
            for row in pred.chunks(d_ffn).take(chunk.len()) {
                let sum: f32 = row.iter().map(|v| v.abs()).sum();
                scores.push(sum / d_ffn as f32);
            }
        }
        Ok(scores)
    }

    /// The executable a T=1 step (decode or ragged prompt tail)
    /// dispatches at one layer — the same selection
    /// [`Engine::run_token`] makes, factored out so the batched step
    /// planner names exactly the executables the sequential path runs.
    /// T=1 steps never carry an attention-sparsity segment: a single
    /// query row has no query block to pool.
    pub(crate) fn token_exe(&self, cfg: &SparsityConfig, sparse: bool,
                            k: usize, s: usize) -> String {
        let d_ffn = self.rt.manifest.model.d_ffn;
        if sparse && k < d_ffn {
            self.fused_sparse_exe(cfg, k, 1, s, None)
                .unwrap_or_else(|| self.exe_name_sparse(None, k, 1, s))
        } else {
            self.exe_name_dense(None, 1, s)
        }
    }

    /// The fused executable a full-block prefill layer step dispatches
    /// under `cfg`, or `None` when the step needs the split pipeline
    /// (ablation expert sources, manifests without fused variants) —
    /// the same selection [`Engine::run_block`] makes. `a` is the
    /// resolved attention drop level ([`Engine::attn_pct`]).
    pub(crate) fn block_exe(&self, cfg: &SparsityConfig, k: usize,
                            s: usize, layer_dense: bool,
                            a: Option<usize>) -> Option<String> {
        if layer_dense {
            return Some(self.exe_name_dense(a, self.block, s));
        }
        self.fused_sparse_exe(cfg, k, self.block, s, a)
    }

    /// Map prefill layer Ks onto the compiled decode-K grid: layers
    /// whose K is not compiled at T=1 run dense during decode.
    pub(crate) fn decode_ks_for(&self, layer_ks: &[usize]) -> Vec<usize> {
        let m = &self.rt.manifest;
        layer_ks
            .iter()
            .map(|&k| {
                if m.decode_k.contains(&k) { k } else { m.model.d_ffn }
            })
            .collect()
    }

    /// Embed a token block of length `t` (t == block or 1).
    pub(crate) fn embed(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let t = tokens.len();
        let out = self.rt.run(
            &format!("embed_t{t}"),
            0,
            &[("tokens", Input::I32(tokens, vec![t]))],
        )?;
        Ok(out.into_iter().next().unwrap().data)
    }

    /// LM head over a t-length hidden block; returns [t * vocab] logits.
    pub(crate) fn lm_head(&self, x: &[f32], t: usize) -> Result<Vec<f32>> {
        let out = self.rt.run(
            &format!("lm_head_t{t}"),
            0,
            &[("x", Input::F32(x, vec![t, self.d]))],
        )?;
        Ok(out.into_iter().next().unwrap().data)
    }

    /// One dense-FFN transformer layer over a t-block; appends KV rows.
    /// `a` is the resolved attention drop level (`None` = dense
    /// attention).
    fn layer_dense(&self, l: usize, x: &[f32], t: usize,
                   cache: &mut SeqKvCache, pos: usize,
                   a: Option<usize>) -> Result<Vec<f32>> {
        let s = cache.bucket;
        let pos_i = [pos as i32];
        let out = self.rt.run(
            &self.exe_name_dense(a, t, s),
            l,
            &[
                ("x", Input::F32(x, vec![t, self.d])),
                ("k_cache", Input::F32(&cache.k[l], vec![s, cache.n_kv, cache.d_head])),
                ("v_cache", Input::F32(&cache.v[l], vec![s, cache.n_kv, cache.d_head])),
                ("pos", Input::I32(&pos_i, vec![])),
            ],
        )?;
        let mut it = out.into_iter();
        let y = it.next().unwrap().data;
        let k_new = it.next().unwrap().data;
        let v_new = it.next().unwrap().data;
        cache.append_layer(l, &k_new, &v_new, t)?;
        Ok(y)
    }

    /// The fused sparse executable the manifest offers for this config,
    /// or `None` → split pipeline. Trained-predictor configs fuse; with
    /// the compensator the classic `layer_sparse_*` is used, without it
    /// the sub-dense `layer_sparse_nc_*` — but only where the manifest
    /// ships it (synthetic manifests do; AOT bundles do not, and fall
    /// back to the split path exactly as before).
    fn fused_sparse_exe(&self, cfg: &SparsityConfig, k: usize, t: usize,
                        s: usize, a: Option<usize>) -> Option<String> {
        if cfg.source != ExpertSource::Trained {
            return None;
        }
        let aseg = Self::a_seg(a);
        let name = if cfg.compensator {
            self.exe_name_sparse(a, k, t, s)
        } else {
            format!("layer_sparse_nc_{aseg}k{k}_t{t}_s{s}")
        };
        self.rt.manifest.has_executable(&name).then_some(name)
    }

    /// One fused sparse layer (trained predictor inside; `exe` selects
    /// the compensated or the no-compensator variant).
    fn layer_sparse_fused(&self, exe: &str, l: usize, x: &[f32], t: usize,
                          cache: &mut SeqKvCache, pos: usize)
                          -> Result<Vec<f32>> {
        let s = cache.bucket;
        let pos_i = [pos as i32];
        let out = self.rt.run(
            exe,
            l,
            &[
                ("x", Input::F32(x, vec![t, self.d])),
                ("k_cache", Input::F32(&cache.k[l], vec![s, cache.n_kv, cache.d_head])),
                ("v_cache", Input::F32(&cache.v[l], vec![s, cache.n_kv, cache.d_head])),
                ("pos", Input::I32(&pos_i, vec![])),
            ],
        )?;
        let mut it = out.into_iter();
        let y = it.next().unwrap().data;
        let k_new = it.next().unwrap().data;
        let v_new = it.next().unwrap().data;
        cache.append_layer(l, &k_new, &v_new, t)?;
        Ok(y)
    }

    /// Split path, attention half: returns h (post-attn residual state)
    /// and appends KV.
    fn layer_attn(&self, l: usize, x: &[f32], cache: &mut SeqKvCache,
                  pos: usize) -> Result<Vec<f32>> {
        let t = self.block;
        let s = cache.bucket;
        let pos_i = [pos as i32];
        let out = self.rt.run(
            &format!("layer_attn_t{t}_s{s}"),
            l,
            &[
                ("x", Input::F32(x, vec![t, self.d])),
                ("k_cache", Input::F32(&cache.k[l], vec![s, cache.n_kv, cache.d_head])),
                ("v_cache", Input::F32(&cache.v[l], vec![s, cache.n_kv, cache.d_head])),
                ("pos", Input::I32(&pos_i, vec![])),
            ],
        )?;
        let mut it = out.into_iter();
        let h = it.next().unwrap().data;
        let k_new = it.next().unwrap().data;
        let v_new = it.next().unwrap().data;
        cache.append_layer(l, &k_new, &v_new, t)?;
        Ok(h)
    }

    /// Split path: neuron scores for expert selection on this block.
    fn neuron_scores(&self, l: usize, h: &[f32],
                     source: ExpertSource) -> Result<Vec<f32>> {
        let t = self.block;
        let exe = match source {
            ExpertSource::Trained => format!("predictor_t{t}"),
            // oracle + first-block-static both read GRIFFIN activation
            // statistics (of the current/first block respectively)
            _ => format!("ffn_acts_t{t}"),
        };
        let out = self
            .rt
            .run(&exe, l, &[("h", Input::F32(h, vec![t, self.d]))])?;
        Ok(out.into_iter().next().unwrap().data)
    }

    /// Split path, FFN half at external indices. Returns the sparse
    /// residual output with (optionally) the compensator term added.
    /// When no compensation is requested and the manifest ships the
    /// `ffn_sparse_nc_*` variant (synthetic manifests), dispatches it
    /// instead: same output values, but the backend never touches
    /// dropped neurons — the sub-dense module the fig6 CPU bench
    /// measures.
    fn ffn_sparse_ext(&self, l: usize, k: usize, h: &[f32], idx: &[i32],
                      compensate: bool) -> Result<Vec<f32>> {
        let t = self.block;
        let inputs = [
            ("h", Input::F32(h, vec![t, self.d])),
            ("idx", Input::I32(idx, vec![idx.len()])),
        ];
        if !compensate {
            let nc = format!("ffn_sparse_nc_k{k}_t{t}");
            if self.rt.manifest.has_executable(&nc) {
                let out = self.rt.run(&nc, l, &inputs)?;
                return Ok(out.into_iter().next().unwrap().data);
            }
        }
        let out = self.rt.run(
            &format!("ffn_sparse_ext_k{k}_t{t}"),
            l,
            &inputs,
        )?;
        let mut it = out.into_iter();
        let mut y = it.next().unwrap().data;
        let comp = it.next().unwrap().data;
        if compensate {
            for (yi, ci) in y.iter_mut().zip(comp.iter()) {
                *yi += ci;
            }
        }
        Ok(y)
    }

    /// Dense FFN half of the split path.
    fn ffn_dense(&self, l: usize, h: &[f32]) -> Result<Vec<f32>> {
        let t = self.block;
        let out = self
            .rt
            .run(&format!("ffn_dense_t{t}"), l,
                 &[("h", Input::F32(h, vec![t, self.d]))])?;
        Ok(out.into_iter().next().unwrap().data)
    }

    /// Grow the cache if the next `t` positions cross the bucket.
    pub(crate) fn ensure_bucket(&self, cache: &mut SeqKvCache, needed: usize)
                     -> Result<()> {
        if needed > cache.bucket {
            let b = self.rt.manifest.bucket_for(needed)?;
            cache.grow(b);
        }
        Ok(())
    }

    /// Process one full 128-token block through all layers.
    /// `static_idx`: per-layer expert indices captured on the first block
    /// (FirstBlockStatic source); filled in when `capture_static`.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_block(&self, x0: Vec<f32>, cache: &mut SeqKvCache, pos: usize,
                 dense: bool, cfg: &SparsityConfig, layer_ks: &[usize],
                 static_idx: &mut Vec<Option<Vec<i32>>>,
                 capture_static: bool) -> Result<Vec<f32>> {
        let d_ffn = self.rt.manifest.model.d_ffn;
        // Attention sparsity applies only to the fused full-block path;
        // the split ablation pipeline below keeps dense attention.
        let a = self.attn_pct(cfg)?;
        let mut x = x0;
        for l in 0..self.n_layers {
            let k = layer_ks[l];
            let layer_dense = dense || k >= d_ffn;
            let fused = if layer_dense || capture_static {
                None
            } else {
                self.fused_sparse_exe(cfg, k, self.block, cache.bucket, a)
            };
            if layer_dense && !capture_static {
                x = self.layer_dense(l, &x, self.block, cache, pos, a)?;
            } else if let Some(exe) = &fused {
                x = self.layer_sparse_fused(exe, l, &x, self.block,
                                            cache, pos)?;
            } else {
                // split path (ablations, and static capture on block 0)
                let h = self.layer_attn(l, &x, cache, pos)?;
                if capture_static {
                    let scores = self.neuron_scores(
                        l, &h, ExpertSource::FirstBlockStatic)?;
                    static_idx[l] = Some(top_k_indices(&scores, k.min(d_ffn)));
                }
                if layer_dense {
                    x = self.ffn_dense(l, &h)?;
                } else {
                    let idx = match cfg.source {
                        ExpertSource::FirstBlockStatic => static_idx[l]
                            .clone()
                            .ok_or_else(|| anyhow!("static idx missing"))?,
                        ExpertSource::Cats => {
                            // threshold at the layer's target density,
                            // then pad/trim to the compiled K shape
                            let scores =
                                self.neuron_scores(l, &h,
                                                   ExpertSource::Cats)?;
                            let th = crate::sparsity::masks::
                                cats_calibrate_threshold(
                                    &scores, k as f64 / d_ffn as f64);
                            let idx = crate::sparsity::masks::
                                cats_threshold_indices(&scores, th);
                            crate::sparsity::masks::pad_indices_to_k(
                                idx, k, d_ffn)
                        }
                        src => {
                            let scores = self.neuron_scores(l, &h, src)?;
                            top_k_indices(&scores, k)
                        }
                    };
                    x = self.ffn_sparse_ext(l, k, &h, &idx,
                                            cfg.compensator)?;
                }
            }
        }
        Ok(x)
    }

    /// One T=1 step through all layers (prompt tail / decode).
    pub(crate) fn run_token(&self, x0: Vec<f32>, cache: &mut SeqKvCache,
                 pos: usize, sparse: bool, cfg: &SparsityConfig,
                 layer_ks: &[usize]) -> Result<Vec<f32>> {
        let d_ffn = self.rt.manifest.model.d_ffn;
        let mut x = x0;
        for l in 0..self.n_layers {
            let k = layer_ks[l];
            if sparse && k < d_ffn {
                // T=1 steps always run the fused trained-predictor op;
                // without the compensator the sub-dense nc variant is
                // preferred where the manifest ships it.
                let exe = self
                    .fused_sparse_exe(cfg, k, 1, cache.bucket, None)
                    .unwrap_or_else(|| {
                        self.exe_name_sparse(None, k, 1, cache.bucket)
                    });
                x = self.layer_sparse_fused(&exe, l, &x, 1, cache, pos)?;
            } else {
                // T=1 steps always run dense attention (no query block
                // to pool), so no attention-sparsity segment here.
                x = self.layer_dense(l, &x, 1, cache, pos, None)?;
            }
        }
        Ok(x)
    }

    /// Block-wise prefill of `tokens` under `cfg`. Returns KV cache, the
    /// last position's hidden state and logits, and the timing breakdown.
    ///
    pub fn prefill(&self, tokens: &[i32],
                   cfg: &SparsityConfig) -> Result<PrefillResult> {
        let mut s = PrefillSession::new(
            self.clone(), tokens.to_vec(), cfg.clone())?;
        while !s.done() {
            s.step()?;
        }
        s.finish()
    }

    /// One decode step: feed `token` at `pos`, return next-token logits.
    pub fn decode_step(&self, token: i32, pos: usize,
                       cache: &mut SeqKvCache, cfg: &SparsityConfig)
                       -> Result<Vec<f32>> {
        self.ensure_bucket(cache, pos + 1)?;
        let layer_ks = self.layer_ks(cfg)?;
        let decode_ks = self.decode_ks_for(&layer_ks);
        let x = self.embed(&[token])?;
        let sparse = !cfg.is_dense() && cfg.sparse_decode;
        let x = self.run_token(x, cache, pos, sparse, cfg, &decode_ks)?;
        cache.advance(1);
        self.lm_head(&x, 1)
    }
}

/// Host-side log-softmax over a logits row.
pub fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse: f32 = logits.iter().map(|&x| (x - max).exp()).sum::<f32>().ln()
        + max;
    logits.iter().map(|&x| x - lse).collect()
}

/// Greedy argmax over logits. Total order (`f32::total_cmp`) with the
/// lowest index winning ties, so the pick is deterministic and a NaN
/// logit can never panic the sampling path (the runtime additionally
/// rejects non-finite activations before they ever reach a sampler).
pub fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| {
            a.1.total_cmp(b.1).then_with(|| b.0.cmp(&a.0))
        })
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_normalizes() {
        let lp = log_softmax(&[1.0, 2.0, 3.0]);
        let total: f32 = lp.iter().map(|&x| x.exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
        assert!(lp.iter().all(|&x| x < 0.0));
    }

    #[test]
    fn argmax_picks_largest() {
        assert_eq!(argmax(&[0.1, 5.0, -2.0]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn sparsity_config_presets() {
        let d = SparsityConfig::dense();
        assert!(d.is_dense());
        let f = SparsityConfig::fastforward(0.5);
        assert_eq!(f.sparsity, Some(0.5));
        assert!(f.layerwise && f.dense_first && f.dense_last && f.compensator);
    }
}
