//! Incremental prefill session: block-level stepping so the dynamic
//! batcher can interleave chunked prefills across requests (Sarathi-style
//! chunked prefill, paper §3.1) and with decode rounds.
//!
//! The session is a *resumable cursor* over the prompt: `next_pos`
//! records exactly how far prefill has progressed, so a scheduler can
//! pause a session for any number of iterations (SLO preemption) at
//! zero cost and resume by simply granting it budget again. When a
//! paused session must give up its KV pages entirely, its resident
//! whole blocks ([`PrefillSession::resident_blocks`]) can be offered to
//! the shared prefix cache and re-adopted on re-admission — the prefill
//! then continues from the same block boundary instead of restarting.

use std::time::Instant;

use anyhow::Result;

use super::{Engine, PrefillResult, PrefillTiming, SparsityConfig};
use crate::kvcache::SeqKvCache;
use crate::sparsity::masks::ExpertSource;

/// One prefill scheduling unit planned as rows of a shared batched
/// pass: the unit's embedded activations plus the per-layer
/// executables the sequential path would dispatch for it (see
/// [`PrefillSession::plan_batch_step`]).
pub(crate) struct ChunkPlan {
    /// Token rows in the unit (the prefill block size, or 1 for a
    /// ragged-tail token).
    pub(crate) t: usize,
    /// Absolute position of the unit's first token.
    pub(crate) pos: usize,
    /// Whether the unit runs the dense path (timing attribution).
    pub(crate) dense: bool,
    /// Embedded input activations, `[t, d_model]`.
    pub(crate) x: Vec<f32>,
    /// Per-layer executable names, exactly what the sequential step
    /// would dispatch.
    pub(crate) exes: Vec<String>,
}

/// State of an in-flight block-wise prefill.
pub struct PrefillSession {
    engine: Engine,
    tokens: Vec<i32>,
    cfg: SparsityConfig,
    layer_ks: Vec<usize>,
    decode_ks: Vec<usize>,
    /// The KV cache being filled (exposed so the executor can copy
    /// prefix-cache rows into it via [`PrefillSession::adopt_prefix`]).
    pub cache: SeqKvCache,
    static_idx: Vec<Option<Vec<i32>>>,
    /// Next prompt position to process (tokens before it are cached).
    pub next_pos: usize,
    x_last: Vec<f32>,
    x_last_is_t1: bool,
    keep_map: Option<Vec<u32>>,
    timing: PrefillTiming,
    started: Instant,
}

impl PrefillSession {
    /// Start a session over `tokens` under `cfg` (no work happens until
    /// the first [`PrefillSession::step`], except the speculative
    /// token-scoring pass when `cfg.token_keep_ratio < 1.0`).
    pub fn new(engine: Engine, tokens: Vec<i32>,
               cfg: SparsityConfig) -> Result<Self> {
        anyhow::ensure!(!tokens.is_empty(), "empty prompt");
        // Fail fast on invalid / unsupported attention-sparsity configs
        // before any prompt work starts (the resolved level itself is
        // recomputed per planned step).
        engine.attn_pct(&cfg)?;
        // Speculative prefill: score every prompt token once and keep
        // only the top `ceil(r · n)` (sink + local bands always
        // survive). The keep-set compacts in place — survivors prefill
        // at consecutive positions 0..n_keep, so no kernel changes are
        // needed and RoPE sees a shorter, contiguous sequence. At
        // keep >= 1.0 the resolver returns None and nothing here runs:
        // the unpruned path stays bit-identical by construction.
        let mut timing = PrefillTiming::default();
        let mut keep_map = None;
        let mut tokens = tokens;
        if let Some(r) = engine.token_keep(&cfg)? {
            let t0 = Instant::now();
            let scores = engine.token_scores(&tokens)?;
            let sel =
                crate::sparsity::tokens::select_tokens(&scores, r);
            timing.score = t0.elapsed();
            if sel.len() < tokens.len() {
                timing.pruned_tokens = tokens.len() - sel.len();
                tokens =
                    sel.iter().map(|&i| tokens[i as usize]).collect();
                keep_map = Some(sel);
            }
        }
        let m = &engine.rt.manifest;
        let layer_ks = engine.layer_ks(&cfg)?;
        let decode_ks = engine.decode_ks_for(&layer_ks);
        let cache = SeqKvCache::new(
            m.model.n_layers,
            m.model.n_kv_heads,
            m.model.d_head,
            m.bucket_for(engine.block().min(tokens.len()))?,
        );
        let n_layers = m.model.n_layers;
        Ok(PrefillSession {
            engine,
            tokens,
            cfg,
            layer_ks,
            decode_ks,
            cache,
            static_idx: vec![None; n_layers],
            next_pos: 0,
            x_last: Vec::new(),
            x_last_is_t1: false,
            keep_map,
            timing,
            started: Instant::now(),
        })
    }

    /// Tokens this session prefills — the pruned prompt under token
    /// pruning, the submitted prompt otherwise.
    pub fn total_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// The token sequence this session actually prefills (pruned under
    /// token pruning). This — not the submitted prompt — is what
    /// prefix-cache keys must hash, since it is what the KV rows hold.
    pub fn effective_tokens(&self) -> &[i32] {
        &self.tokens
    }

    /// Speculative-prefill keep map: ascending original prompt indices
    /// of the surviving tokens, or `None` when the prompt is prefilled
    /// whole. `cache` row `i` belongs to original token `keep_map[i]`.
    pub fn keep_map(&self) -> Option<&[u32]> {
        self.keep_map.as_deref()
    }

    /// Prompt tokens not yet processed.
    pub fn remaining_tokens(&self) -> usize {
        self.tokens.len() - self.next_pos
    }

    /// Whether every prompt token has been processed.
    pub fn done(&self) -> bool {
        self.next_pos >= self.tokens.len()
    }

    /// Timing and block counts accumulated so far. `total` and
    /// `lm_head` are only final after [`PrefillSession::finish`]; the
    /// block/tail counters are always current — the executor uses them
    /// to account blocks executed by sessions that fail mid-prefill.
    pub fn timing(&self) -> &PrefillTiming {
        &self.timing
    }

    /// Adopt `n_tokens` of already-computed KV from the prefix cache
    /// instead of executing those blocks.
    ///
    /// Must be called before the first [`PrefillSession::step`].
    /// `n_tokens` must be a whole number of blocks and strictly less
    /// than the prompt length — at least one token is always computed so
    /// the session still produces last-position logits. `copy` receives
    /// the (pre-grown) session cache and must fill exactly `n_tokens`
    /// positions (e.g. [`crate::kvcache::PrefixHit::copy_into`]).
    pub fn adopt_prefix<F>(&mut self, n_tokens: usize, copy: F) -> Result<()>
    where
        F: FnOnce(&mut SeqKvCache) -> Result<()>,
    {
        let block = self.engine.block();
        anyhow::ensure!(self.next_pos == 0, "adopt after prefill started");
        anyhow::ensure!(self.cache.len == 0, "adopt into non-empty cache");
        anyhow::ensure!(
            n_tokens > 0 && n_tokens % block == 0,
            "adoption must cover whole blocks (got {n_tokens})"
        );
        anyhow::ensure!(
            n_tokens < self.tokens.len(),
            "adoption must leave at least one token to prefill"
        );
        anyhow::ensure!(
            self.cfg.prefix_cacheable(),
            "configuration is not prefix-cacheable"
        );
        self.engine.ensure_bucket(&mut self.cache, n_tokens)?;
        copy(&mut self.cache)?;
        anyhow::ensure!(
            self.cache.len == n_tokens,
            "prefix copy filled {} of {n_tokens} positions",
            self.cache.len
        );
        self.next_pos = n_tokens;
        self.timing.adopted_blocks = n_tokens / block;
        Ok(())
    }

    /// Whole blocks of KV currently resident in the session's cache
    /// (adopted + executed). This is what a scheduler can salvage into
    /// the prefix cache when ejecting a preempted session: on
    /// re-admission the blocks are adopted back and the prefill resumes
    /// from the same block boundary.
    pub fn resident_blocks(&self) -> usize {
        self.next_pos / self.engine.block()
    }

    /// Number of scheduling units left (full blocks + tail tokens).
    pub fn remaining_steps(&self) -> usize {
        let block = self.engine.block();
        let rem = self.remaining_tokens();
        rem / block + rem % block
    }

    /// Process the next scheduling unit: one full 128-token block, or one
    /// tail token. Returns the number of tokens consumed.
    pub fn step(&mut self) -> Result<usize> {
        assert!(!self.done(), "step on finished session");
        let block = self.engine.block();
        let pos = self.next_pos;
        let remaining = self.tokens.len() - pos;
        let engine = self.engine.clone();

        if remaining >= block {
            engine.ensure_bucket(&mut self.cache, pos + block)?;
            let blk = &self.tokens[pos..pos + block];
            let t0 = Instant::now();
            let x = engine.embed(blk)?;
            self.timing.embed += t0.elapsed();

            let is_first = pos == 0;
            let is_last = remaining == block; // no tail after this block
            let dense = self.cfg.is_dense()
                || (self.cfg.dense_first && is_first)
                || (self.cfg.dense_last && is_last);
            let capture_static = self.cfg.source
                == ExpertSource::FirstBlockStatic
                && is_first
                && !self.cfg.is_dense();
            let t1 = Instant::now();
            self.x_last = engine.run_block(
                x, &mut self.cache, pos, dense, &self.cfg, &self.layer_ks,
                &mut self.static_idx, capture_static,
            )?;
            self.timing.layers += t1.elapsed();
            self.x_last_is_t1 = false;
            self.cache.advance(block);
            self.next_pos += block;
            self.timing.blocks += 1;
            if dense {
                self.timing.dense_blocks += 1;
            }
            Ok(block)
        } else {
            // ragged tail: T=1 steps (dense under dense_last)
            engine.ensure_bucket(&mut self.cache, pos + 1)?;
            let t0 = Instant::now();
            let x = engine.embed(&[self.tokens[pos]])?;
            self.timing.embed += t0.elapsed();
            let sparse_tail = !self.cfg.is_dense() && !self.cfg.dense_last;
            let t1 = Instant::now();
            self.x_last = engine.run_token(
                x, &mut self.cache, pos, sparse_tail, &self.cfg,
                &self.decode_ks,
            )?;
            self.timing.layers += t1.elapsed();
            self.x_last_is_t1 = true;
            self.cache.advance(1);
            self.next_pos += 1;
            self.timing.tail_tokens += 1;
            Ok(1)
        }
    }

    /// Plan this session's next scheduling unit as rows of a shared
    /// batched pass (continuous batching), or `None` when the unit
    /// must run through the split sequential pipeline instead —
    /// first-block static capture, and sparse blocks whose expert
    /// source has no fused executable (Oracle / CATS / static-index
    /// ablations). Grows the KV bucket and embeds the unit's tokens;
    /// on `Some`, the caller runs the returned per-layer executables
    /// over the returned activations and then hands the final
    /// activations to [`PrefillSession::complete_batch_step`]. On
    /// `None` nothing was consumed — the caller falls back to
    /// [`PrefillSession::step`].
    pub(crate) fn plan_batch_step(&mut self) -> Result<Option<ChunkPlan>> {
        assert!(!self.done(), "plan on finished session");
        let engine = self.engine.clone();
        let block = engine.block();
        let pos = self.next_pos;
        let remaining = self.tokens.len() - pos;
        let n_layers = self.layer_ks.len();
        let d_ffn = engine.rt.manifest.model.d_ffn;
        if remaining >= block {
            let is_first = pos == 0;
            let is_last = remaining == block; // no tail after this block
            let dense = self.cfg.is_dense()
                || (self.cfg.dense_first && is_first)
                || (self.cfg.dense_last && is_last);
            let capture_static = self.cfg.source
                == ExpertSource::FirstBlockStatic
                && is_first
                && !self.cfg.is_dense();
            if capture_static {
                return Ok(None);
            }
            engine.ensure_bucket(&mut self.cache, pos + block)?;
            // Resolved once per planned block; T=1 tail rows below stay
            // dense-attention (token_exe passes no attention segment).
            let a = engine.attn_pct(&self.cfg)?;
            let mut exes = Vec::with_capacity(n_layers);
            for l in 0..n_layers {
                let k = self.layer_ks[l];
                let layer_dense = dense || k >= d_ffn;
                match engine.block_exe(&self.cfg, k, self.cache.bucket,
                                       layer_dense, a) {
                    Some(exe) => exes.push(exe),
                    None => return Ok(None), // split pipeline required
                }
            }
            let t0 = Instant::now();
            let x = engine.embed(&self.tokens[pos..pos + block])?;
            self.timing.embed += t0.elapsed();
            Ok(Some(ChunkPlan {
                t: block,
                pos,
                dense,
                x,
                exes,
            }))
        } else {
            // ragged tail: a T=1 row, always expressible as a batch row
            engine.ensure_bucket(&mut self.cache, pos + 1)?;
            let sparse_tail = !self.cfg.is_dense() && !self.cfg.dense_last;
            let exes = (0..n_layers)
                .map(|l| {
                    engine.token_exe(&self.cfg, sparse_tail,
                                     self.decode_ks[l], self.cache.bucket)
                })
                .collect();
            let t0 = Instant::now();
            let x = engine.embed(&[self.tokens[pos]])?;
            self.timing.embed += t0.elapsed();
            Ok(Some(ChunkPlan {
                t: 1,
                pos,
                dense: false,
                x,
                exes,
            }))
        }
    }

    /// Fold a batched step's outputs back into the session: keep the
    /// final activations for [`PrefillSession::finish`], advance the
    /// cursor and record the same timing counters
    /// [`PrefillSession::step`] would.
    pub(crate) fn complete_batch_step(&mut self, plan: &ChunkPlan,
                                      x_out: Vec<f32>,
                                      layers: std::time::Duration) {
        self.x_last = x_out;
        self.x_last_is_t1 = plan.t == 1;
        self.timing.layers += layers;
        self.cache.advance(plan.t);
        self.next_pos += plan.t;
        if plan.t == 1 {
            self.timing.tail_tokens += 1;
        } else {
            self.timing.blocks += 1;
            if plan.dense {
                self.timing.dense_blocks += 1;
            }
        }
    }

    /// Finish: compute last-position hidden + logits.
    pub fn finish(mut self) -> Result<PrefillResult> {
        assert!(self.done(), "finish before all blocks processed");
        let engine = self.engine.clone();
        let m = &engine.rt.manifest.model;
        let t2 = Instant::now();
        let (last_hidden, last_logits) = if self.x_last_is_t1 {
            let logits = engine.lm_head(&self.x_last, 1)?;
            (std::mem::take(&mut self.x_last), logits)
        } else {
            let block = engine.block();
            let d = m.d_model;
            let logits_all = engine.lm_head(&self.x_last, block)?;
            let h = self.x_last[(block - 1) * d..].to_vec();
            let logits = logits_all[(block - 1) * m.vocab..].to_vec();
            (h, logits)
        };
        self.timing.lm_head = t2.elapsed();
        self.timing.total = self.started.elapsed();
        Ok(PrefillResult {
            cache: self.cache,
            last_hidden,
            last_logits,
            timing: self.timing,
            keep_map: self.keep_map,
        })
    }
}
