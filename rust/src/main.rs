//! FastForward CLI: the L3 coordinator entrypoint.
//!
//! Subcommands:
//! * `serve`    — start the HTTP serving stack (router → batcher → engine)
//! * `cluster`  — prefix-affinity front tier over N `serve` worker
//!   processes (spawned as children, or attached via `--worker-addrs`)
//! * `generate` — one-shot generation from the command line
//! * `eval`     — run the longbench-sim accuracy harness
//! * `schedule` — print the calibrated layerwise sparsity schedule
//! * `cost`     — cost-model exploration (crossovers, speedup curves)
//! * `info`     — artifact + model summary

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};
use fastforward::batcher::BatcherConfig;
use fastforward::cost::CostModel;
use fastforward::engine::{Engine, SparsityConfig};
use fastforward::eval::{self, EvalSpec};
use fastforward::manifest::{Manifest, SyntheticSpec};
use fastforward::metrics::Metrics;
use fastforward::pool::ExecutorPool;
use fastforward::router::{LoadEstimator, Router};
use fastforward::runtime::{BackendKind, Runtime};
use fastforward::server::Server;
use fastforward::sparsity::masks::ExpertSource;
use fastforward::tokenizer::Tokenizer;
use fastforward::util::cli::Args;
use fastforward::weights::WeightStore;

fn usage() -> ! {
    eprintln!(
        "fastforward <serve|cluster|generate|eval|schedule|cost|info> [flags]
  common:    --artifacts DIR (default ./artifacts)
             --backend cpu|pjrt (execution backend; default pjrt when
              compiled with the pjrt feature, cpu otherwise. cpu needs
              no artifacts: it serves the deterministic synthetic
              reference model, and is incompatible with --artifacts)
             --cpu-threads N (cpu backend worker lanes per engine;
              default FF_CPU_THREADS, else available cores capped at 8.
              thread count never changes a single output bit)
             --cpu-kernel scalar|simd (cpu inner-kernel tier; default
              FF_CPU_KERNEL, else scalar. scalar is bit-identical to
              the sequential reference; simd is deterministic and
              thread-invariant but re-associates reductions, so it is
              validated under a ULP tolerance tier instead)
             --weight-precision f32|bf16|int8 (synthetic weight
              storage; default FF_WEIGHT_PREC, else f32. bf16 stores
              weights rounded-to-nearest-even; int8 stores symmetric
              absmax codes + per-column-tile f32 scales; both
              dequantize in-register and accumulate in f32)
             --attn-sparsity A (block-sparse attention for full prefill
              blocks: fraction of optional causal key blocks dropped,
              0..1; 0 = dense attention. Quantized onto the manifest's
              compiled grid. Orthogonal to --sparsity)
             --token-keep-ratio R (speculative prefill: score every
              prompt token once with the low-rank predictor, keep the
              top ceil(R*n) tokens — sink + local bands always kept —
              and prefill only the survivors at compacted positions.
              1.0 = bit-identical to the unpruned path; orthogonal to
              --sparsity / --attn-sparsity)
  serve:     --addr HOST:PORT --sparsity S --max-active N --queue N
             --replicas N (executor pool size, default 1)
             --prefix-cache-mb MB (shared prefix KV cache, default 64;
              0 disables) --kv-pages N --block-budget N
             --decode-first-budget N (prefill trickle while interactive
              decodes run, default 1)
             --max-batch N (max sequence rows per batched forward pass
              — decode rows + one prefill chunk; default 8, 1 =
              sequential execution)
             --no-slo (disable SLO-aware
              scheduling: priority, decode-first, preemption)
             --flop-load-model (FLOP-weighted dispatch cost)
  cluster:   --addr HOST:PORT (front listen address)
             --workers N (spawn N child `serve` worker processes on
              loopback ephemeral ports; serve flags like --backend,
              --replicas, --sparsity, --prefix-cache-mb, --queue are
              forwarded to each worker)
             --worker-addrs HOST:PORT,... (attach to already-running
              workers instead of spawning; mutually exclusive with
              --workers)
             --dispatch affinity|random (placement policy; default
              affinity = consistent-hash on the prompt's leading
              prefix-block chain, least-loaded fallback when the
              affine worker is saturated)
             --key-blocks N (leading full blocks in the routing key,
              default 4) --vnodes N (ring points per worker, default 64)
             --max-inflight N (per-worker backplane bound, default 32;
              all workers at the bound sheds 429)
             --quota-rps R --quota-burst B (per-tenant token-bucket
              admission keyed on the request's \"tenant\" field;
              rps <= 0 disables, default off)
             --health-interval-ms MS (worker /readyz probe period,
              default 500) --fail-threshold N (consecutive probe
              failures before a worker is routed around, default 3)
  generate:  --prompt TEXT --max-tokens N --sparsity S
  eval:      --sparsity LIST --tasks N --prompt-chars N --ablation NAME
  cost:      --model llama8b|llama1b|llama3b|artifact --sparsity LIST
  schedule:  (no flags)
  tpu-estimate: per-kernel VMEM/MXU/roofline report (DESIGN.md §8)
  analyze:   sparsity error accumulation vs context (--sparsity S)"
    );
    std::process::exit(2);
}

fn backend_kind(args: &Args) -> Result<BackendKind> {
    let s = args.str("backend", BackendKind::default_for_build().label());
    BackendKind::parse(&s)
        .ok_or_else(|| anyhow!("unknown backend {s:?} (expected cpu|pjrt)"))
}

/// Resolve `--backend`/`--artifacts` into (backend, artifact dir).
///
/// The CPU backend serves the deterministic synthetic reference model
/// — it cannot execute artifact bundles (their fused low-rank
/// predictor/compensator networks are PJRT-only). Combining it with an
/// explicit `--artifacts` is therefore an error, never a silent
/// substitution; an artifact bundle sitting at the *default* path is
/// ignored with a notice.
fn resolve_backend(args: &Args)
                   -> Result<(BackendKind, Option<std::path::PathBuf>)> {
    let kind = backend_kind(args)?;
    let dir = std::path::PathBuf::from(args.str("artifacts", "artifacts"));
    match kind {
        BackendKind::Pjrt => Ok((kind, Some(dir))),
        BackendKind::Cpu => {
            anyhow::ensure!(
                !args.has("artifacts"),
                "--backend cpu serves the synthetic reference model and \
                 cannot execute the artifact bundle at {dir:?}; drop \
                 --artifacts or use --backend pjrt"
            );
            if dir.join("manifest.json").exists() {
                eprintln!(
                    "[backend] cpu: ignoring artifact bundle at {dir:?} \
                     (synthetic reference model; use --backend pjrt to \
                     execute artifacts)"
                );
            }
            Ok((kind, None))
        }
    }
}

fn load_engine(args: &Args) -> Result<Engine> {
    match resolve_backend(args)? {
        (_, None) => {
            let mut spec = SyntheticSpec::default();
            spec.weight_precision =
                fastforward::weights::WeightPrecision::from_env();
            Engine::synthetic_cpu(&spec)
        }
        (kind, Some(dir)) => {
            let manifest = Arc::new(Manifest::load(&dir)?);
            let weights = Arc::new(WeightStore::load(&manifest)?);
            let rt =
                Arc::new(Runtime::with_backend(kind, manifest, weights)?);
            Ok(Engine::new(rt))
        }
    }
}

fn cfg_from_args(args: &Args) -> SparsityConfig {
    let sp = args.f64("sparsity", 0.0);
    // Attention drop is orthogonal to FFN sparsity: it applies on the
    // dense branch too (attention-only sparse configs are valid).
    let attn = args.f64("attn-sparsity", 0.0);
    let attn = (attn > 0.0).then_some(attn);
    // Speculative-prefill token pruning is likewise orthogonal; 1.0
    // (or unset) means every prompt token prefills.
    let keep = args.f64("token-keep-ratio", 1.0);
    let keep = (keep < 1.0).then_some(keep);
    if sp > 0.0 {
        let mut cfg = SparsityConfig::fastforward(sp);
        cfg.layerwise = !args.has("uniform");
        cfg.dense_first = !args.has("no-dense-first");
        cfg.dense_last = !args.has("no-dense-last");
        cfg.compensator = !args.has("no-compensator");
        cfg.sparse_decode = args.has("sparse-decode");
        cfg.source = match args.str("source", "trained").as_str() {
            "oracle" => ExpertSource::Oracle,
            "static" => ExpertSource::FirstBlockStatic,
            "cats" => ExpertSource::Cats,
            _ => ExpertSource::Trained,
        };
        cfg.attn_sparsity = attn;
        cfg.token_keep_ratio = keep;
        cfg
    } else {
        let mut cfg = SparsityConfig::dense();
        cfg.attn_sparsity = attn;
        cfg.token_keep_ratio = keep;
        cfg
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let m = engine.manifest();
    println!("model          : {}", m.model.name);
    println!(
        "dims           : d_model={} d_ffn={} layers={} heads={} kv={} block={}",
        m.model.d_model, m.model.d_ffn, m.model.n_layers, m.model.n_heads,
        m.model.n_kv_heads, m.model.block
    );
    println!("buckets        : {:?}", m.model.buckets);
    println!("k grid         : {:?} (decode: {:?})", m.k_grid, m.decode_k);
    println!("executables    : {}", m.executables.len());
    println!(
        "attention mass : {:?}",
        m.schedule
            .attention_masses
            .iter()
            .map(|x| (x * 10.0).round() / 10.0)
            .collect::<Vec<_>>()
    );
    for (k, b) in &m.schedule.budgets {
        println!("schedule {k}  : K={:?} uniform={:?}", b.layer_k,
                 b.uniform_k);
    }
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.str("artifacts", "artifacts"));
    let manifest = Manifest::load(&dir)?;
    println!("layer  attn-mass  K@30%  K@40%  K@50%");
    let s = &manifest.schedule;
    for l in 0..manifest.model.n_layers {
        let k = |key: &str| {
            s.budgets.get(key).map(|b| b.layer_k[l]).unwrap_or(0)
        };
        println!(
            "{l:5}  {:9.2}  {:5}  {:5}  {:5}",
            s.attention_masses[l],
            k("0.30"),
            k("0.40"),
            k("0.50")
        );
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let tok = Tokenizer::new(engine.manifest().model.vocab);
    let prompt = args.str("prompt", "the quick brown fox");
    let cfg = cfg_from_args(args);
    let r = engine.generate(
        &tok.encode(&prompt),
        args.usize("max-tokens", 48),
        &cfg,
    )?;
    println!("--- generation ({} tokens) ---", r.tokens.len());
    println!("{}", r.text);
    println!(
        "--- ttft {:.1} ms | tpot {:.2} ms | blocks {} ({} dense) ---",
        r.ttft_ms, r.tpot_ms, r.prefill.blocks, r.prefill.dense_blocks
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let spec = EvalSpec {
        tasks_per_group: args.usize("tasks", 4),
        prompt_chars: args.usize("prompt-chars", 1024),
        seed: args.usize("seed", 17) as u64,
        with_generation: args.has("with-generation"),
        max_gen_tokens: args.usize("max-tokens", 16),
    };
    let tasks = eval::build_tasks(&spec);
    println!("{}", eval::TABLE_HEADER);
    let dense = eval::evaluate(&engine, &tasks, &SparsityConfig::dense(),
                               &spec)?;
    println!("{}", eval::format_row("dense (0%)", &dense, 0.0));
    for sp in args.f64_list("sparsity", &[0.3, 0.4, 0.5]) {
        let mut cfg = cfg_from_args(args);
        cfg.sparsity = Some(sp);
        let r = eval::evaluate(&engine, &tasks, &cfg, &spec)?;
        println!(
            "{}",
            eval::format_row(
                &format!("fastforward {:.0}%", sp * 100.0),
                &r,
                r.rel_gap_pct(dense.average)
            )
        );
    }
    Ok(())
}

fn cmd_cost(args: &Args) -> Result<()> {
    let model = args.str("model", "llama8b");
    let cm = match model.as_str() {
        "llama8b" => CostModel::llama8b(),
        "llama3b" => CostModel::llama3b(),
        "llama1b" => CostModel::llama1b(),
        _ => {
            let engine = load_engine(args)?;
            CostModel::from_cfg(&engine.manifest().model)
        }
    };
    println!("model {model}: attention/FFN FLOP crossover at {} tokens",
             cm.attn_ffn_crossover());
    println!("ctx      dense-GFLOP  ffn%   speedup@30%  @40%  @50%");
    for ctx in [512usize, 1024, 2048, 4096, 8192, 16384, 32768] {
        let c = cm.dense_prefill(ctx);
        let mut row = format!(
            "{ctx:7}  {:11.2}  {:4.1}%",
            c.total() / 1e9,
            100.0 * c.ffn() / c.total()
        );
        for sp in [0.3, 0.4, 0.5] {
            let dens = vec![1.0 - sp; cm.n_layers];
            row += &format!("  {:10.3}x", cm.speedup(ctx, &dens, true, true));
        }
        println!("{row}");
    }
    Ok(())
}

fn cmd_tpu_estimate(args: &Args) -> Result<()> {
    use fastforward::cost::tpu;
    let engine = load_engine(args)?;
    let m = &engine.manifest().model;
    println!("TPU-v4 structural estimate for {} kernels (DESIGN.md §8)", m.name);
    println!("{:-<100}", "");
    println!("{:<28} {:>10} {:>8} {:>12} {:>12} {:>10}",
             "kernel step", "VMEM KiB", "fits?", "FLOP/byte",
             "roofline TF/s", "eff ratio");
    for p in tpu::report(m.d_model, m.d_ffn, m.d_head,
                         m.d_model / 16, m.ftile) {
        println!(
            "{:<28} {:>10} {:>8} {:>12.1} {:>12.2} {:>9.2}",
            p.name,
            p.vmem_bytes / 1024,
            if p.fits_vmem() { "yes" } else { "NO" },
            p.arithmetic_intensity(),
            p.roofline_tflops(),
            p.efficiency_ratio(),
        );
    }
    println!("\nPaper-scale (LLaMA-8B, d=4096, ftile=128):");
    for p in tpu::report(4096, 14336, 128, 256, 128) {
        println!(
            "{:<28} {:>10} {:>8} {:>12.1} {:>12.2} {:>9.2}",
            p.name,
            p.vmem_bytes / 1024,
            if p.fits_vmem() { "yes" } else { "NO" },
            p.arithmetic_intensity(),
            p.roofline_tflops(),
            p.efficiency_ratio(),
        );
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    use fastforward::eval::analysis;
    use fastforward::trace::WordBank;
    use fastforward::util::rng::Rng;
    let engine = load_engine(args)?;
    let tok = Tokenizer::new(engine.manifest().model.vocab);
    let max_ctx = engine.manifest().model.max_ctx;
    let ctxs: Vec<usize> = args
        .usize_list("ctx", &[256, 512, 1024, 2048])
        .into_iter()
        .filter(|&c| c <= max_ctx)
        .collect();
    let make_prompt = |len: usize| {
        let mut rng = Rng::new(13);
        let bank = WordBank::new(&mut rng, 128);
        let mut t = tok.encode(&bank.filler(&mut rng, len));
        t.truncate(len);
        t
    };

    println!("sparsity-induced logit error vs context (paper §3.3:");
    println!("errors accumulate with depth/length; the compensator bounds them)\n");
    println!("{:>8} {:>12} {:>12} {:>14} {:>14}",
             "ctx", "rel-L2", "cosine", "rel-L2 (no-comp)", "cos (no-comp)");
    let mut cfg = cfg_from_args(args);
    if cfg.is_dense() {
        cfg = SparsityConfig::fastforward(0.5);
    }
    let mut nc = cfg.clone();
    nc.compensator = false;
    for &ctx in &ctxs {
        let prompt = make_prompt(ctx);
        let with = analysis::compare_configs(
            &engine, &prompt, &SparsityConfig::dense(), &cfg)?;
        let without = analysis::compare_configs(
            &engine, &prompt, &SparsityConfig::dense(), &nc)?;
        println!(
            "{ctx:>8} {:>12.4} {:>12.4} {:>14.4} {:>14.4}",
            with.logit_rel_l2, with.logit_cos,
            without.logit_rel_l2, without.logit_cos
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.str("addr", "127.0.0.1:8080");
    let metrics = Arc::new(Metrics::new());
    let (kind, dir) = resolve_backend(args)?;
    // Probe the manifest on the main thread for fail-fast UX; the CPU
    // backend serves the synthetic reference model.
    let probe = match &dir {
        Some(d) => Manifest::load(d)?,
        None => Manifest::synthetic(&SyntheticSpec::default()),
    };
    let max_ctx = probe.model.max_ctx;
    let vocab = probe.model.vocab;
    let block = probe.model.block;
    let replicas = args.usize("replicas", 1).max(1);
    // Default pool: 8 max-length sequences *per replica*, so scaling the
    // pool out doesn't silently starve KV admission.
    let kv_pages = args.usize(
        "kv-pages",
        replicas * 8 * max_ctx.div_ceil(block),
    );
    let estimator = if args.has("flop-load-model") {
        LoadEstimator::from_cost_model(&CostModel::from_cfg(&probe.model))
    } else {
        LoadEstimator::new(block)
    };
    let router = Arc::new(Router::new_pooled(
        args.usize("queue", 64),
        max_ctx,
        kv_pages,
        block,
        metrics.clone(),
        replicas,
        estimator,
        args.usize("prefix-cache-mb", 64) * (1 << 20),
    ));

    // One executor thread per replica; each owns its engine (the PJRT
    // runtime is single-threaded, so parallelism comes from replicas).
    let bcfg = BatcherConfig {
        max_active: args.usize("max-active", 8),
        prefill_block_budget: args.usize("block-budget", 4),
        decode_first_budget: args.usize("decode-first-budget", 1),
        max_batch: args.usize("max-batch", 8).max(1),
        slo: !args.has("no-slo"),
    };
    let slo_on = bcfg.slo;
    let max_batch = bcfg.max_batch;
    let pool = ExecutorPool::spawn_backend(router.clone(), bcfg, kind, dir);
    eprintln!(
        "[serve] {} backend, {replicas} replica(s), {} KV pages, prefix \
         cache {} MiB, max batch {max_batch}, SLO scheduling {}",
        kind.label(),
        kv_pages,
        args.usize("prefix-cache-mb", 64),
        if slo_on { "on" } else { "off" }
    );

    let default_sparsity = {
        let s = args.f64("sparsity", 0.5);
        if s > 0.0 { Some(s) } else { None }
    };
    let default_attn_sparsity = {
        let a = args.f64("attn-sparsity", 0.0);
        if a > 0.0 { Some(a) } else { None }
    };
    let default_token_keep = {
        let k = args.f64("token-keep-ratio", 1.0);
        if k < 1.0 { Some(k) } else { None }
    };
    let server = Arc::new(Server {
        router: router.clone(),
        metrics,
        tokenizer: Tokenizer::new(vocab),
        default_sparsity,
        default_attn_sparsity,
        default_token_keep,
        lifecycle: fastforward::server::Lifecycle::new(),
        header_timeout: Duration::from_millis(
            args.usize("header-timeout-ms", 5000) as u64,
        ),
    });
    let res = server.serve(&addr);
    router.close();
    let _ = pool.join();
    res
}

/// `serve` flags forwarded verbatim to each spawned cluster worker.
const WORKER_FLAGS: &[&str] = &[
    "backend", "artifacts", "replicas", "sparsity", "attn-sparsity",
    "token-keep-ratio", "prefix-cache-mb", "queue", "kv-pages",
    "max-active", "block-budget", "decode-first-budget", "max-batch",
    "no-slo", "flop-load-model", "cpu-threads", "cpu-kernel",
    "weight-precision", "header-timeout-ms",
];

/// Reserve a loopback `host:port` by binding port 0 and releasing it.
/// The tiny bind race is acceptable here (same pattern the test suite
/// uses): workers re-bind the port milliseconds later.
fn free_loopback_addr() -> Result<String> {
    let l = std::net::TcpListener::bind("127.0.0.1:0")?;
    Ok(l.local_addr()?.to_string())
}

fn cmd_cluster(args: &Args) -> Result<()> {
    use fastforward::cluster::{wait_ready, ClusterConfig, ClusterFront,
                               DispatchMode};
    let addr = args.str("addr", "127.0.0.1:8080");
    let metrics = Arc::new(Metrics::new());
    // Probe the model config the workers will serve: routing keys must
    // walk the same prefill block size the worker prefix caches use.
    let (_kind, dir) = resolve_backend(args)?;
    let probe = match &dir {
        Some(d) => Manifest::load(d)?,
        None => Manifest::synthetic(&SyntheticSpec::default()),
    };
    let dispatch_s = args.str("dispatch", "affinity");
    let dispatch = DispatchMode::parse(&dispatch_s).ok_or_else(|| {
        anyhow!("unknown --dispatch {dispatch_s:?} \
                 (expected affinity|random)")
    })?;
    let cfg = ClusterConfig {
        dispatch,
        block: probe.model.block,
        key_blocks: args.usize("key-blocks", 4),
        vnodes: args.usize("vnodes", 64),
        max_inflight: args.usize("max-inflight", 32).max(1),
        quota_rps: args.f64("quota-rps", 0.0),
        quota_burst: args.f64("quota-burst", 8.0),
        vocab: probe.model.vocab,
        health_interval: Duration::from_millis(
            args.usize("health-interval-ms", 500) as u64,
        ),
        fail_threshold: args.usize("fail-threshold", 3).max(1) as u32,
        ..ClusterConfig::default()
    };

    let mut children: Vec<std::process::Child> = Vec::new();
    let workers: Vec<String> = match args.opt_str("worker-addrs") {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        None => {
            let n = args.usize("workers", 2).max(1);
            let exe = std::env::current_exe()?;
            let mut addrs = Vec::with_capacity(n);
            for _ in 0..n {
                let waddr = free_loopback_addr()?;
                let mut cmd = std::process::Command::new(&exe);
                cmd.arg("serve").arg("--addr").arg(&waddr);
                for flag in WORKER_FLAGS {
                    if let Some(v) = args.opt_str(flag) {
                        cmd.arg(format!("--{flag}"));
                        if v != fastforward::util::cli::FLAG_SET {
                            cmd.arg(v);
                        }
                    }
                }
                children.push(cmd.spawn()?);
                addrs.push(waddr);
            }
            addrs
        }
    };
    anyhow::ensure!(!workers.is_empty(), "cluster needs >= 1 worker");

    let res = (|| -> Result<()> {
        for w in &workers {
            wait_ready(w, Duration::from_secs(60))?;
        }
        eprintln!(
            "[cluster] {} worker(s) ready: {}",
            workers.len(),
            workers.join(", ")
        );
        ClusterFront::new(workers.clone(), cfg, metrics).serve(&addr)
    })();
    for mut c in children {
        let _ = c.kill();
        let _ = c.wait();
    }
    res
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    // `--cpu-threads N` is forwarded through the FF_CPU_THREADS env var
    // so every construction site (serve replicas, one-shot engines)
    // resolves the same count; done before any thread spawns.
    if let Some(n) = args.opt_str("cpu-threads") {
        std::env::set_var(
            fastforward::util::threadpool::THREADS_ENV,
            n,
        );
    }
    // `--cpu-kernel` / `--weight-precision` forward the same way
    // (FF_CPU_KERNEL / FF_WEIGHT_PREC), validated up front so a typo
    // errors instead of silently falling back to the default tier.
    if let Some(k) = args.opt_str("cpu-kernel") {
        if fastforward::runtime::CpuKernel::parse(&k).is_none() {
            return Err(anyhow!(
                "unknown --cpu-kernel {k:?} (expected scalar|simd)"
            ));
        }
        std::env::set_var(fastforward::runtime::KERNEL_ENV, k);
    }
    if let Some(p) = args.opt_str("weight-precision") {
        if fastforward::weights::WeightPrecision::parse(&p).is_none() {
            return Err(anyhow!(
                "unknown --weight-precision {p:?} \
                 (expected f32|bf16|int8)"
            ));
        }
        std::env::set_var(fastforward::weights::PRECISION_ENV, p);
    }
    match args.positional.first().map(|s| s.as_str()) {
        Some("serve") => cmd_serve(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("generate") => cmd_generate(&args),
        Some("eval") => cmd_eval(&args),
        Some("schedule") => cmd_schedule(&args),
        Some("cost") => cmd_cost(&args),
        Some("info") => cmd_info(&args),
        Some("tpu-estimate") => cmd_tpu_estimate(&args),
        Some("analyze") => cmd_analyze(&args),
        _ => usage(),
    }
}
