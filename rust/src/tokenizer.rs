//! Byte-level tokenizer, the exact mirror of python/compile/corpus.py.
//!
//! Token space: 0..=255 raw bytes, 256 = <pad>, 257 = <bos>, 258 = <eos>;
//! the LM-head vocabulary is padded to `vocab` (384 by default) for tidy
//! matmul shapes — ids ≥ 259 never occur in text and the model learns to
//! assign them ~zero probability.

/// Padding token id.
pub const PAD: i32 = 256;
/// Beginning-of-sequence token id.
pub const BOS: i32 = 257;
/// End-of-sequence token id (terminates greedy decoding).
pub const EOS: i32 = 258;

/// The byte-level tokenizer (ids 0..=255 are raw bytes).
#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// LM-head vocabulary size (>= 259 to cover the specials).
    pub vocab: usize,
}

impl Tokenizer {
    /// Tokenizer for a model with the given padded vocabulary.
    pub fn new(vocab: usize) -> Self {
        assert!(vocab > EOS as usize, "vocab must cover specials");
        Tokenizer { vocab }
    }

    /// Encode text as its UTF-8 bytes (one token per byte).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    /// Decode, skipping special / out-of-range ids; invalid UTF-8 is
    /// replaced (matching python's errors="replace").
    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .filter(|&&t| (0..256).contains(&t))
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Pad (with PAD) or truncate to exactly `len` tokens.
    pub fn pad_to(&self, mut tokens: Vec<i32>, len: usize) -> Vec<i32> {
        tokens.truncate(len);
        while tokens.len() < len {
            tokens.push(PAD);
        }
        tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = Tokenizer::new(384);
        let s = "the quick brown fox! 123";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn roundtrip_utf8() {
        let t = Tokenizer::new(384);
        let s = "héllo → wörld";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn specials_skipped_in_decode() {
        let t = Tokenizer::new(384);
        let mut toks = t.encode("ab");
        toks.push(EOS);
        toks.push(PAD);
        assert_eq!(t.decode(&toks), "ab");
    }

    #[test]
    fn pad_to_len() {
        let t = Tokenizer::new(384);
        let padded = t.pad_to(t.encode("abc"), 8);
        assert_eq!(padded.len(), 8);
        assert_eq!(&padded[3..], &[PAD; 5]);
        let truncated = t.pad_to(t.encode("abcdef"), 2);
        assert_eq!(truncated, vec![b'a' as i32, b'b' as i32]);
    }

    #[test]
    fn property_roundtrip_random_bytes() {
        let t = Tokenizer::new(384);
        crate::util::proptest::check("tok-roundtrip", 64, |r| {
            let n = r.range(0, 200);
            let s: String = (0..n)
                .map(|_| (b'a' + r.range(0, 26) as u8) as char)
                .collect();
            if t.decode(&t.encode(&s)) == s {
                Ok(())
            } else {
                Err(format!("roundtrip failed for {s:?}"))
            }
        });
    }
}
