//! Byte-level tokenizer, the exact mirror of python/compile/corpus.py.
//!
//! Token space: 0..=255 raw bytes, 256 = <pad>, 257 = <bos>, 258 = <eos>;
//! the LM-head vocabulary is padded to `vocab` (384 by default) for tidy
//! matmul shapes — ids ≥ 259 never occur in text and the model learns to
//! assign them ~zero probability.

/// Padding token id.
pub const PAD: i32 = 256;
/// Beginning-of-sequence token id.
pub const BOS: i32 = 257;
/// End-of-sequence token id (terminates greedy decoding).
pub const EOS: i32 = 258;

/// The byte-level tokenizer (ids 0..=255 are raw bytes).
#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// LM-head vocabulary size (>= 259 to cover the specials).
    pub vocab: usize,
}

impl Tokenizer {
    /// Tokenizer for a model with the given padded vocabulary.
    pub fn new(vocab: usize) -> Self {
        assert!(vocab > EOS as usize, "vocab must cover specials");
        Tokenizer { vocab }
    }

    /// Encode text as its UTF-8 bytes (one token per byte).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    /// Decode, skipping special / out-of-range ids; invalid UTF-8 is
    /// replaced (matching python's errors="replace").
    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .filter(|&&t| (0..256).contains(&t))
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Pad (with PAD) or truncate to exactly `len` tokens.
    pub fn pad_to(&self, mut tokens: Vec<i32>, len: usize) -> Vec<i32> {
        tokens.truncate(len);
        while tokens.len() < len {
            tokens.push(PAD);
        }
        tokens
    }
}

/// Incremental UTF-8 assembler for byte-level token streaming.
///
/// Each streamed token is one byte; a multi-byte character only becomes
/// valid text once its last byte arrives. `StreamDecoder` buffers the
/// bytes of an incomplete character and emits maximal valid UTF-8 as
/// soon as it completes, so SSE clients always receive well-formed
/// text. Special/out-of-range ids are skipped, matching
/// [`Tokenizer::decode`].
///
/// ```
/// use fastforward::tokenizer::StreamDecoder;
///
/// let mut d = StreamDecoder::new();
/// // "é" is two bytes: nothing emitted until the second arrives
/// let bytes = "é".as_bytes();
/// assert_eq!(d.push(bytes[0] as i32), "");
/// assert_eq!(d.push(bytes[1] as i32), "é");
/// assert_eq!(d.push(b'!' as i32), "!");
/// assert_eq!(d.finish(), "");
/// ```
#[derive(Debug, Default)]
pub struct StreamDecoder {
    pending: Vec<u8>,
}

impl StreamDecoder {
    /// Fresh decoder with no pending bytes.
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed one token id; returns whatever text it completes (possibly
    /// empty mid-character). Ids outside the byte range are skipped.
    pub fn push(&mut self, token: i32) -> String {
        if !(0..256).contains(&token) {
            return String::new();
        }
        self.pending.push(token as u8);
        self.drain_valid()
    }

    /// Flush any trailing incomplete bytes as replacement characters
    /// (end of stream).
    pub fn finish(&mut self) -> String {
        let out = String::from_utf8_lossy(&self.pending).into_owned();
        self.pending.clear();
        out
    }

    /// Extract the maximal valid UTF-8 prefix of `pending`, replacing
    /// definitively-invalid sequences and keeping a possibly-incomplete
    /// trailing character buffered.
    fn drain_valid(&mut self) -> String {
        let mut out = String::new();
        loop {
            match std::str::from_utf8(&self.pending) {
                Ok(s) => {
                    out.push_str(s);
                    self.pending.clear();
                    return out;
                }
                Err(e) => {
                    let valid = e.valid_up_to();
                    out.push_str(
                        std::str::from_utf8(&self.pending[..valid])
                            .unwrap(),
                    );
                    match e.error_len() {
                        // invalid bytes in the middle: replace and keep
                        // scanning the rest
                        Some(bad) => {
                            out.push('\u{fffd}');
                            self.pending.drain(..valid + bad);
                        }
                        // incomplete trailing character: keep buffered
                        None => {
                            self.pending.drain(..valid);
                            return out;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = Tokenizer::new(384);
        let s = "the quick brown fox! 123";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn roundtrip_utf8() {
        let t = Tokenizer::new(384);
        let s = "héllo → wörld";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn specials_skipped_in_decode() {
        let t = Tokenizer::new(384);
        let mut toks = t.encode("ab");
        toks.push(EOS);
        toks.push(PAD);
        assert_eq!(t.decode(&toks), "ab");
    }

    #[test]
    fn pad_to_len() {
        let t = Tokenizer::new(384);
        let padded = t.pad_to(t.encode("abc"), 8);
        assert_eq!(padded.len(), 8);
        assert_eq!(&padded[3..], &[PAD; 5]);
        let truncated = t.pad_to(t.encode("abcdef"), 2);
        assert_eq!(truncated, vec![b'a' as i32, b'b' as i32]);
    }

    #[test]
    fn stream_decoder_matches_batch_decode() {
        let t = Tokenizer::new(384);
        let s = "héllo → wörld!";
        let toks = t.encode(s);
        let mut d = StreamDecoder::new();
        let mut streamed = String::new();
        for &tok in &toks {
            streamed.push_str(&d.push(tok));
        }
        streamed.push_str(&d.finish());
        assert_eq!(streamed, s, "incremental == batch decode");
    }

    #[test]
    fn stream_decoder_skips_specials_and_flushes_partials() {
        let mut d = StreamDecoder::new();
        assert_eq!(d.push(EOS), "");
        assert_eq!(d.push(PAD), "");
        assert_eq!(d.push(b'a' as i32), "a");
        // lone continuation byte: definitively invalid → replacement
        assert_eq!(d.push(0x80), "\u{fffd}");
        // leading byte of a 2-byte char, stream ends before the rest
        assert_eq!(d.push(0xC3), "");
        let tail = d.finish();
        assert_eq!(tail, "\u{fffd}", "incomplete tail flushed lossily");
        assert_eq!(d.finish(), "", "finish is idempotent");
    }

    /// Fuzz the incremental decoder: random UTF-8 strings mixing 1- to
    /// 4-byte codepoints, fed one byte-token at a time (the finest
    /// possible chunking, so every multi-byte character straddles a
    /// boundary), with specials interleaved at random. The reassembled
    /// stream must equal the original string exactly, and the decoder
    /// must agree with the batch decoder.
    #[test]
    fn prop_stream_decoder_reassembles_any_utf8() {
        let t = Tokenizer::new(384);
        crate::util::proptest::check("stream-utf8", 300, |r| {
            let n = r.range(0, 64);
            let s: String = (0..n)
                .map(|_| {
                    // sample across UTF-8 widths: ascii, latin, CJK,
                    // and astral (4-byte) planes
                    let c = match r.range(0, 4) {
                        0 => r.range(0x20, 0x7F) as u32,
                        1 => r.range(0xA1, 0x250) as u32,
                        2 => r.range(0x4E00, 0x9FFF) as u32,
                        _ => r.range(0x1F300, 0x1F600) as u32,
                    };
                    char::from_u32(c).unwrap()
                })
                .collect();
            let mut tokens = t.encode(&s);
            // interleave specials at random positions: they must be
            // invisible to the stream
            for _ in 0..r.range(0, 4) {
                let at = r.range(0, tokens.len() + 1);
                tokens.insert(at, [PAD, BOS, EOS][r.range(0, 3)]);
            }
            let mut d = StreamDecoder::new();
            let mut streamed = String::new();
            for &tok in &tokens {
                streamed.push_str(&d.push(tok));
            }
            streamed.push_str(&d.finish());
            if streamed != s {
                return Err(format!(
                    "stream reassembly diverged: {streamed:?} != {s:?}"
                ));
            }
            if t.decode(&tokens) != streamed {
                return Err("stream != batch decode".to_string());
            }
            Ok(())
        });
    }

    /// A multi-byte character interrupted by a special token is two
    /// invalid fragments, not a character — the decoder must replace,
    /// never panic, and keep byte counts consistent.
    #[test]
    fn stream_decoder_split_by_special_is_replaced() {
        let bytes = "é".as_bytes(); // 2 bytes: 0xC3 0xA9
        let mut d = StreamDecoder::new();
        assert_eq!(d.push(bytes[0] as i32), "");
        // the special does not flush or corrupt the pending byte
        assert_eq!(d.push(EOS), "");
        assert_eq!(d.push(bytes[1] as i32), "é", "specials are invisible");
        assert_eq!(d.finish(), "");
    }

    #[test]
    fn property_roundtrip_random_bytes() {
        let t = Tokenizer::new(384);
        crate::util::proptest::check("tok-roundtrip", 64, |r| {
            let n = r.range(0, 200);
            let s: String = (0..n)
                .map(|_| (b'a' + r.range(0, 26) as u8) as char)
                .collect();
            if t.decode(&t.encode(&s)) == s {
                Ok(())
            } else {
                Err(format!("roundtrip failed for {s:?}"))
            }
        });
    }
}
