//! Reusable dispatch-policy primitives shared by the in-process
//! [`crate::router::Router`] and the multi-process cluster front tier
//! ([`crate::cluster`]):
//!
//! * [`least_loaded`] — the pure placement rule both tiers apply when
//!   affinity is unavailable or saturated (lowest outstanding load among
//!   routable candidates with queue room, ties toward the lowest index);
//! * [`HashRing`] — consistent-hash assignment with virtual nodes, so a
//!   worker death re-homes only its own arc of the key space instead of
//!   reshuffling every prefix;
//! * [`TokenBucket`] / [`TenantQuotas`] — per-tenant admission control
//!   (millions-of-users hygiene: one hot tenant sheds with 429 instead
//!   of starving everyone's prefix-affine workers).
//!
//! Everything here is pure state + explicit clocks (an [`Instant`] is
//! *passed in*, never read): deterministic to unit-test, free of I/O,
//! and usable under any lock discipline the caller prefers.

use std::collections::HashMap;
use std::time::Instant;

// ---------------------------------------------------------------------------
// Least-loaded placement
// ---------------------------------------------------------------------------

/// One placement candidate's admission snapshot, as seen by
/// [`least_loaded`]. The caller samples these under whatever locking it
/// uses; the pick itself is pure.
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    /// Caller-meaningful index (replica id, worker slot).
    pub idx: usize,
    /// Routable at all (not dead / not health-checked out).
    pub alive: bool,
    /// Below its queue/inflight bound — a live-but-full candidate
    /// contributes to `alive` accounting but is never picked.
    pub has_room: bool,
    /// Outstanding load estimate (queued + in-flight cost).
    pub load: f64,
}

/// Why [`least_loaded`] could not place a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PickError {
    /// Every alive candidate is at its bound — shed with 429 semantics.
    Saturated,
    /// No candidate is alive at all — shed with 503 semantics.
    NoneAlive,
}

/// The least-loaded alive candidate *among those with room* (ties break
/// toward the lowest `idx`, so placement is deterministic under equal
/// load). Exactly the [`crate::router::Router`] dispatch rule, extracted
/// so the cluster front applies the identical policy across worker
/// processes.
pub fn least_loaded<I>(candidates: I) -> Result<usize, PickError>
where
    I: IntoIterator<Item = Candidate>,
{
    let mut any_alive = false;
    let mut best: Option<(f64, usize)> = None;
    for c in candidates {
        if !c.alive {
            continue;
        }
        any_alive = true;
        if !c.has_room {
            continue;
        }
        match best {
            Some((b, i)) if b < c.load || (b == c.load && i < c.idx) => {}
            _ => best = Some((c.load, c.idx)),
        }
    }
    match best {
        Some((_, i)) => Ok(i),
        None if any_alive => Err(PickError::Saturated),
        None => Err(PickError::NoneAlive),
    }
}

// ---------------------------------------------------------------------------
// Consistent-hash ring
// ---------------------------------------------------------------------------

/// SplitMix64 finalizer — the point-placement mix for ring positions.
/// Deterministic across processes, so every front replica computes the
/// same ring for the same worker count.
fn mix64(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Consistent-hash ring over `n` workers with `vnodes` virtual points
/// each.
///
/// [`HashRing::assign`] maps a 64-bit routing key (the prompt's leading
/// block-chain hash, [`crate::kvcache::routing_key`]) to the first
/// *routable* worker clockwise from the key's ring position. Virtual
/// nodes keep per-worker arcs balanced; when a worker dies, only keys
/// on its arcs re-home (to each arc's clockwise successor) — every
/// other prompt keeps hitting the worker whose prefix cache is already
/// warm.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// `(ring_position, worker)` sorted by position.
    points: Vec<(u64, usize)>,
    workers: usize,
}

impl HashRing {
    /// A ring over `workers` workers with `vnodes` points each (both
    /// clamped to ≥ 1).
    pub fn new(workers: usize, vnodes: usize) -> Self {
        let workers = workers.max(1);
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(workers * vnodes);
        for w in 0..workers {
            for v in 0..vnodes {
                let pos = mix64(
                    (w as u64).wrapping_mul(0x9e3779b97f4a7c15)
                        ^ mix64(v as u64 + 1),
                );
                points.push((pos, w));
            }
        }
        points.sort_unstable();
        HashRing { points, workers }
    }

    /// Number of workers the ring was built over.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The worker owning `key`: the first ring point clockwise from
    /// `key` whose worker satisfies `routable`, wrapping at the top.
    /// `None` when no worker is routable.
    pub fn assign<F>(&self, key: u64, routable: F) -> Option<usize>
    where
        F: Fn(usize) -> bool,
    {
        if self.points.is_empty() {
            return None;
        }
        let start = self
            .points
            .partition_point(|&(pos, _)| pos < key);
        let n = self.points.len();
        for step in 0..n {
            let (_, w) = self.points[(start + step) % n];
            if routable(w) {
                return Some(w);
            }
        }
        None
    }
}

// ---------------------------------------------------------------------------
// Token-bucket quotas
// ---------------------------------------------------------------------------

/// A classic token bucket: `burst` capacity refilled at `rate` tokens
/// per second. The clock is passed in, so tests drive it
/// deterministically.
#[derive(Debug, Clone)]
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    last: Option<Instant>,
}

impl TokenBucket {
    /// A bucket refilling at `rate`/s with `burst` capacity, born full.
    pub fn new(rate: f64, burst: f64) -> Self {
        let burst = burst.max(1.0);
        TokenBucket { rate: rate.max(0.0), burst, tokens: burst,
                      last: None }
    }

    /// Take `cost` tokens at time `now`; `false` means over quota
    /// (nothing is deducted on refusal).
    pub fn try_take(&mut self, now: Instant, cost: f64) -> bool {
        if let Some(last) = self.last {
            let dt = now.saturating_duration_since(last).as_secs_f64();
            self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        }
        self.last = Some(now);
        if self.tokens + 1e-9 >= cost {
            self.tokens -= cost;
            true
        } else {
            false
        }
    }
}

/// Per-tenant admission control: one [`TokenBucket`] per tenant id,
/// created on first sight with the shared `rate`/`burst`. A
/// non-positive rate disables quotas entirely (every request admitted)
/// — the single-tenant default.
#[derive(Debug)]
pub struct TenantQuotas {
    rate: f64,
    burst: f64,
    buckets: HashMap<String, TokenBucket>,
}

impl TenantQuotas {
    /// Quotas of `rate` requests/s with `burst` headroom per tenant;
    /// `rate <= 0` disables enforcement.
    pub fn new(rate: f64, burst: f64) -> Self {
        TenantQuotas { rate, burst, buckets: HashMap::new() }
    }

    /// Whether quotas are enforced at all.
    pub fn enabled(&self) -> bool {
        self.rate > 0.0
    }

    /// Admit one request from `tenant` at `now`; `false` = over quota
    /// (shed with 429).
    pub fn admit(&mut self, tenant: &str, now: Instant) -> bool {
        if !self.enabled() {
            return true;
        }
        self.buckets
            .entry(tenant.to_string())
            .or_insert_with(|| TokenBucket::new(self.rate, self.burst))
            .try_take(now, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn cand(idx: usize, alive: bool, has_room: bool, load: f64)
            -> Candidate {
        Candidate { idx, alive, has_room, load }
    }

    #[test]
    fn least_loaded_picks_lowest_load_with_room() {
        let picked = least_loaded([
            cand(0, true, true, 5.0),
            cand(1, true, true, 2.0),
            cand(2, true, false, 0.0), // full: never picked
        ])
        .unwrap();
        assert_eq!(picked, 1);
    }

    #[test]
    fn least_loaded_ties_break_to_lowest_idx() {
        let picked = least_loaded([
            cand(2, true, true, 1.0),
            cand(0, true, true, 1.0),
            cand(1, true, true, 1.0),
        ])
        .unwrap();
        assert_eq!(picked, 0);
    }

    #[test]
    fn least_loaded_distinguishes_saturated_from_dead() {
        assert_eq!(
            least_loaded([cand(0, true, false, 0.0)]),
            Err(PickError::Saturated)
        );
        assert_eq!(
            least_loaded([cand(0, false, true, 0.0)]),
            Err(PickError::NoneAlive)
        );
        assert_eq!(least_loaded([]), Err(PickError::NoneAlive));
    }

    #[test]
    fn ring_is_deterministic_and_total() {
        let ring = HashRing::new(4, 64);
        let again = HashRing::new(4, 64);
        for k in 0..1000u64 {
            let key = k.wrapping_mul(0x9e3779b97f4a7c15);
            let w = ring.assign(key, |_| true).unwrap();
            assert!(w < 4);
            assert_eq!(again.assign(key, |_| true), Some(w),
                       "same ring, same key, same worker");
        }
    }

    #[test]
    fn ring_spreads_keys_roughly_evenly() {
        let ring = HashRing::new(4, 64);
        let mut counts = [0usize; 4];
        for k in 0..4000u64 {
            let key = mix64(k + 1);
            counts[ring.assign(key, |_| true).unwrap()] += 1;
        }
        for (w, &c) in counts.iter().enumerate() {
            assert!(
                (400..=2200).contains(&c),
                "worker {w} owns {c}/4000 keys — ring badly unbalanced: \
                 {counts:?}"
            );
        }
    }

    #[test]
    fn ring_death_rehomes_only_dead_arcs() {
        let ring = HashRing::new(4, 64);
        let mut moved = 0usize;
        let total = 4000usize;
        for k in 0..total as u64 {
            let key = mix64(k + 1);
            let before = ring.assign(key, |_| true).unwrap();
            let after = ring.assign(key, |w| w != 2).unwrap();
            assert_ne!(after, 2);
            if before != 2 {
                assert_eq!(before, after,
                           "keys off the dead worker must not move");
            } else {
                moved += 1;
            }
        }
        assert!(moved > 0, "worker 2 owned nothing?");
        assert!(moved < total / 2,
                "death of 1/4 workers re-homed {moved}/{total} keys");
        // nobody routable → None, never a spin
        assert_eq!(ring.assign(12345, |_| false), None);
    }

    #[test]
    fn token_bucket_enforces_rate_and_burst() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(10.0, 2.0);
        // burst of 2 from a full bucket
        assert!(b.try_take(t0, 1.0));
        assert!(b.try_take(t0, 1.0));
        assert!(!b.try_take(t0, 1.0), "burst exhausted");
        // 100ms at 10/s refills exactly one token
        let t1 = t0 + Duration::from_millis(100);
        assert!(b.try_take(t1, 1.0));
        assert!(!b.try_take(t1, 1.0));
        // refill caps at burst
        let t2 = t1 + Duration::from_secs(60);
        assert!(b.try_take(t2, 2.0));
        assert!(!b.try_take(t2, 1.0));
    }

    #[test]
    fn tenant_quotas_isolate_tenants() {
        let t0 = Instant::now();
        let mut q = TenantQuotas::new(1.0, 1.0);
        assert!(q.admit("a", t0));
        assert!(!q.admit("a", t0), "tenant a over quota");
        assert!(q.admit("b", t0), "tenant b unaffected");
        // disabled quotas admit everything
        let mut open = TenantQuotas::new(0.0, 1.0);
        for _ in 0..100 {
            assert!(open.admit("a", t0));
        }
    }
}
