//! Multi-process serving tier: a standalone front that dispatches over
//! N `serve` worker processes through a loopback-HTTP backplane,
//! turning per-worker prefix caches into one cluster-wide cache.
//!
//! Routing *is* the cache policy: a repeated long prompt only skips
//! prefill if it lands on the worker already holding its KV blocks, so
//! the front keys every request on the prompt's leading block-chain
//! hash ([`crate::kvcache::routing_key`] — the same walk the workers'
//! [`crate::kvcache::PrefixCache`] performs) and places it on a
//! consistent-hash ring ([`policy::HashRing`]). Same leading blocks →
//! same worker → warm cache; a worker death re-homes only its own arcs.
//!
//! The backplane is plain HTTP/1.1 over loopback: the front re-issues
//! the client's `POST /generate` body to the chosen worker and pipes
//! the response bytes back verbatim — one-shot JSON and SSE streams
//! proxy identically (`Connection: close` framing end-to-end, no
//! transfer-encoding to re-chunk).
//!
//! Admission control ("millions of users" hygiene):
//! * per-tenant token-bucket quotas ([`policy::TenantQuotas`], keyed on
//!   the request's `"tenant"` field) shed hot tenants with 429;
//! * per-worker in-flight caps bound the backplane — an affine worker
//!   at its cap falls back to the least-loaded routable worker
//!   ([`policy::least_loaded`], the identical rule the in-process
//!   router applies), and when every worker is at its cap the front
//!   sheds with 429 instead of queueing unboundedly;
//! * no routable worker at all → 503.
//!
//! Worker lifecycle: a health-checker thread probes each worker's
//! `/readyz` every [`ClusterConfig::health_interval`]; after
//! [`ClusterConfig::fail_threshold`] consecutive failures the worker is
//! marked dead and the ring routes around it (mark-dead + re-hash); a
//! later successful probe revives it. Draining a worker (`POST
//! /admin/drain`) flips its `/healthz`+`/readyz` to 503, so the checker
//! stops routing new work to it while its in-flight streams finish.

pub mod policy;

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::kvcache::routing_key;
use crate::metrics::{ClusterRoute, Metrics};
use crate::server::{error_json, read_request, respond};
use crate::tokenizer::Tokenizer;
use crate::util::json;

use policy::{Candidate, HashRing, PickError, TenantQuotas};

// ---------------------------------------------------------------------------
// Minimal loopback-HTTP client (health checks, tests, benches)
// ---------------------------------------------------------------------------

/// One blocking HTTP/1.1 request against a numeric `host:port` address
/// with connect/read/write deadlines. Returns `(status, body)`; the
/// response must be `Connection: close`-framed (which every server in
/// this crate is).
pub fn http_request(addr: &str, method: &str, path: &str, body: &str,
                    timeout: Duration) -> Result<(u16, String)> {
    let sock: SocketAddr = addr
        .parse()
        .with_context(|| format!("bad worker address {addr:?}"))?;
    let mut s = TcpStream::connect_timeout(&sock, timeout)?;
    s.set_read_timeout(Some(timeout))?;
    s.set_write_timeout(Some(timeout))?;
    let _ = s.set_nodelay(true);
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    )?;
    let mut out = String::new();
    s.read_to_string(&mut out)?;
    parse_response(&out)
}

/// `GET path` against a worker ([`http_request`] without a body).
pub fn http_get(addr: &str, path: &str, timeout: Duration)
                -> Result<(u16, String)> {
    http_request(addr, "GET", path, "", timeout)
}

/// `POST path` with a JSON body ([`http_request`]).
pub fn http_post(addr: &str, path: &str, body: &str, timeout: Duration)
                 -> Result<(u16, String)> {
    http_request(addr, "POST", path, body, timeout)
}

/// Split a raw `Connection: close` HTTP response into (status, body).
fn parse_response(raw: &str) -> Result<(u16, String)> {
    let status = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| anyhow!("malformed response: {raw:?}"))?;
    let body = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    Ok((status, body))
}

// ---------------------------------------------------------------------------
// Config + worker state
// ---------------------------------------------------------------------------

/// How the front places requests on workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchMode {
    /// Consistent-hash prefix affinity with least-loaded fallback — the
    /// production policy.
    Affinity,
    /// Uniform-random placement over routable workers — the baseline
    /// the fig15 harness compares against.
    Random,
}

impl DispatchMode {
    /// Parse a `--dispatch` flag value.
    pub fn parse(s: &str) -> Option<DispatchMode> {
        match s {
            "affinity" => Some(DispatchMode::Affinity),
            "random" => Some(DispatchMode::Random),
            _ => None,
        }
    }
}

/// Front-tier tuning knobs (`ff cluster` flags; see
/// docs/OPERATIONS.md §6).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Placement policy.
    pub dispatch: DispatchMode,
    /// Prefill block size of the model the workers serve — routing keys
    /// must walk the same block granularity the worker prefix caches
    /// use, or affinity degenerates to random.
    pub block: usize,
    /// Leading full blocks folded into the routing key. More blocks
    /// discriminate longer shared prefixes; fewer spread a workload
    /// whose prompts all share one template. 4 is a good default.
    pub key_blocks: usize,
    /// Seed of the routing-key chain. Any constant works (it need not
    /// match the workers' internal sparsity fingerprints — placement
    /// only needs *consistency*); all front replicas must agree.
    pub routing_seed: u64,
    /// Virtual nodes per worker on the hash ring.
    pub vnodes: usize,
    /// Per-worker in-flight cap — the bounded backplane queue. At the
    /// cap the affine worker falls back; all workers at cap sheds 429.
    pub max_inflight: usize,
    /// Per-tenant sustained requests/second (`<= 0` disables quotas).
    pub quota_rps: f64,
    /// Per-tenant burst headroom in requests.
    pub quota_burst: f64,
    /// Vocabulary of the byte tokenizer used to key prompts (must match
    /// the workers' model).
    pub vocab: usize,
    /// Health-check period.
    pub health_interval: Duration,
    /// Consecutive failed probes before a worker is marked dead.
    pub fail_threshold: u32,
    /// Connect/probe deadline for backplane requests.
    pub connect_timeout: Duration,
    /// Per-read deadline while proxying a response (bounds a hung
    /// worker; each SSE token write resets it).
    pub proxy_read_timeout: Duration,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            dispatch: DispatchMode::Affinity,
            block: 128,
            key_blocks: 4,
            routing_seed: 0xFF_C1_05_7E,
            vnodes: 64,
            max_inflight: 32,
            quota_rps: 0.0,
            quota_burst: 8.0,
            vocab: 384,
            health_interval: Duration::from_millis(500),
            fail_threshold: 3,
            connect_timeout: Duration::from_millis(1000),
            proxy_read_timeout: Duration::from_secs(120),
        }
    }
}

/// One backplane worker as the front sees it.
#[derive(Debug)]
pub struct Worker {
    addr: String,
    /// Requests currently proxied to this worker.
    inflight: AtomicUsize,
    /// Passed its last health probe (starts `true`: workers are waited
    /// on at startup, and an optimistic start never *adds* traffic to a
    /// dead worker for long — the first probe corrects it).
    healthy: AtomicBool,
    /// Consecutive failed probes.
    fails: AtomicUsize,
}

impl Worker {
    fn new(addr: String) -> Self {
        Worker {
            addr,
            inflight: AtomicUsize::new(0),
            healthy: AtomicBool::new(true),
            fails: AtomicUsize::new(0),
        }
    }

    /// The worker's `host:port`.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Currently proxied requests.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    /// Passed its last health probe.
    pub fn healthy(&self) -> bool {
        self.healthy.load(Ordering::Acquire)
    }
}

/// Decrements a worker's in-flight gauge on scope exit (success, error
/// or panic alike), so a failed proxy can never leak capacity.
struct InflightGuard<'a> {
    worker: &'a Worker,
}

impl<'a> InflightGuard<'a> {
    fn enter(worker: &'a Worker) -> Self {
        worker.inflight.fetch_add(1, Ordering::AcqRel);
        InflightGuard { worker }
    }
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.worker.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

// ---------------------------------------------------------------------------
// The front tier
// ---------------------------------------------------------------------------

/// The standalone front: consistent-hash prefix-affinity dispatch over
/// worker processes, quota/shed admission, health-checked lifecycle.
///
/// Endpoints: `POST /generate` (routed + proxied), `GET /healthz`
/// (front liveness), `GET /readyz` (≥ 1 routable worker), `GET
/// /metrics` (`ff_cluster_*` series).
pub struct ClusterFront {
    workers: Vec<Arc<Worker>>,
    ring: HashRing,
    cfg: ClusterConfig,
    quotas: Mutex<TenantQuotas>,
    tokenizer: Tokenizer,
    /// Shared metrics registry (exported on the front's `/metrics`).
    pub metrics: Arc<Metrics>,
    shutdown: AtomicBool,
    /// Resolved listen address once `serve`/`spawn` has bound.
    bound: Mutex<Option<SocketAddr>>,
    /// Counter feeding the random-dispatch baseline.
    rr: AtomicU64,
}

impl ClusterFront {
    /// A front over `worker_addrs` (each a `host:port` of a running
    /// `serve` process).
    pub fn new(worker_addrs: Vec<String>, cfg: ClusterConfig,
               metrics: Arc<Metrics>) -> Arc<ClusterFront> {
        assert!(!worker_addrs.is_empty(), "cluster needs ≥1 worker");
        metrics.ensure_cluster_workers(worker_addrs.len());
        let ring = HashRing::new(worker_addrs.len(), cfg.vnodes);
        let quotas = TenantQuotas::new(cfg.quota_rps, cfg.quota_burst);
        let tokenizer = Tokenizer::new(cfg.vocab);
        Arc::new(ClusterFront {
            workers: worker_addrs
                .into_iter()
                .map(|a| Arc::new(Worker::new(a)))
                .collect(),
            ring,
            cfg,
            quotas: Mutex::new(quotas),
            tokenizer,
            metrics,
            shutdown: AtomicBool::new(false),
            bound: Mutex::new(None),
            rr: AtomicU64::new(0),
        })
    }

    /// The worker table (health/inflight snapshots for tests + benches).
    pub fn workers(&self) -> &[Arc<Worker>] {
        &self.workers
    }

    fn routable(&self, w: usize) -> bool {
        self.workers[w].healthy()
    }

    /// Routing key for a prompt's token ids — exposed so benches can
    /// pre-compute placements.
    pub fn key_for(&self, tokens: &[i32]) -> u64 {
        routing_key(self.cfg.routing_seed, tokens, self.cfg.block,
                    self.cfg.key_blocks)
    }

    /// Place one request: `Ok((worker, route))` or `Err(status)` to
    /// shed (429 = all routable workers at their in-flight cap, 503 =
    /// none routable).
    fn place(&self, key: u64) -> std::result::Result<(usize, ClusterRoute),
                                                     u16> {
        let room = |w: usize| {
            self.workers[w].inflight() < self.cfg.max_inflight
        };
        if self.cfg.dispatch == DispatchMode::Random {
            // uniform over routable workers with room: the baseline
            // still sheds like affinity does, it just ignores the key
            let n = self.workers.len();
            let tick = self.rr.fetch_add(1, Ordering::Relaxed);
            let start = (mix_tick(tick) % n as u64) as usize;
            let mut any_routable = false;
            for i in 0..n {
                let w = (start + i) % n;
                if !self.routable(w) {
                    continue;
                }
                any_routable = true;
                if room(w) {
                    return Ok((w, ClusterRoute::Random));
                }
            }
            return Err(if any_routable { 429 } else { 503 });
        }
        if let Some(w) = self.ring.assign(key, |w| self.routable(w)) {
            if room(w) {
                return Ok((w, ClusterRoute::Affine));
            }
            // affine worker saturated: least-loaded fallback, same rule
            // as the in-process router
            let picked =
                policy::least_loaded(self.workers.iter().enumerate().map(
                    |(i, wk)| Candidate {
                        idx: i,
                        alive: wk.healthy(),
                        has_room: room(i),
                        load: wk.inflight() as f64,
                    },
                ));
            return match picked {
                Ok(i) => Ok((i, ClusterRoute::Fallback)),
                Err(PickError::Saturated) => Err(429),
                Err(PickError::NoneAlive) => Err(503),
            };
        }
        Err(503)
    }

    /// Serve forever on `addr` (port 0 binds ephemeral; see
    /// [`ClusterFront::spawn`] for the handle-returning variant).
    /// Starts the health-checker thread, then accepts connections until
    /// [`ClusterFront::stop`].
    pub fn serve(self: Arc<Self>, addr: &str) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        self.serve_on(listener)
    }

    /// [`ClusterFront::serve`] over an already-bound listener.
    pub fn serve_on(self: Arc<Self>, listener: TcpListener) -> Result<()> {
        let local = listener.local_addr()?;
        *self.bound.lock().unwrap() = Some(local);
        eprintln!(
            "[cluster] front on {local}: {} workers, {:?} dispatch",
            self.workers.len(),
            self.cfg.dispatch
        );
        let checker = {
            let this = self.clone();
            std::thread::spawn(move || this.health_loop())
        };
        for stream in listener.incoming() {
            if self.shutdown.load(Ordering::Acquire) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let this = self.clone();
            std::thread::spawn(move || {
                let mut stream = stream;
                if let Err(e) = this.handle(&mut stream) {
                    let _ = respond(
                        &mut stream,
                        500,
                        "application/json",
                        &error_json(&e.to_string()),
                    );
                }
            });
        }
        let _ = checker.join();
        Ok(())
    }

    /// Bind `addr`, then serve on a background thread. Returns the
    /// resolved address (so `addr` may use port 0) and the serving
    /// thread's handle.
    pub fn spawn(self: Arc<Self>, addr: &str)
                 -> Result<(SocketAddr, std::thread::JoinHandle<()>)> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let this = self;
        let handle = std::thread::spawn(move || {
            let _ = this.serve_on(listener);
        });
        Ok((local, handle))
    }

    /// Stop accepting connections and end the health-checker. In-flight
    /// proxies finish on their own threads.
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::Release);
        // poke the accept loop so it observes the flag
        if let Some(addr) = *self.bound.lock().unwrap() {
            let _ = TcpStream::connect_timeout(
                &addr,
                Duration::from_millis(200),
            );
        }
    }

    /// One pass of health probes (also called periodically by the
    /// checker thread). Public so tests drive it deterministically.
    pub fn probe_workers(&self) {
        for (i, w) in self.workers.iter().enumerate() {
            let ok = matches!(
                http_get(&w.addr, "/readyz", self.cfg.connect_timeout),
                Ok((200, _))
            );
            if ok {
                w.fails.store(0, Ordering::Release);
                w.healthy.store(true, Ordering::Release);
            } else {
                let f = w.fails.fetch_add(1, Ordering::AcqRel) + 1;
                if f as u32 >= self.cfg.fail_threshold {
                    w.healthy.store(false, Ordering::Release);
                }
            }
            self.metrics.set_cluster_worker(i, w.healthy(), w.inflight());
        }
    }

    fn health_loop(&self) {
        while !self.shutdown.load(Ordering::Acquire) {
            self.probe_workers();
            std::thread::sleep(self.cfg.health_interval);
        }
    }

    fn handle(&self, stream: &mut TcpStream) -> Result<()> {
        // same slow-loris discipline as the worker server
        let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
        let req = read_request(stream);
        let _ = stream.set_read_timeout(None);
        let req = match req {
            Ok(Ok(req)) => req,
            Ok(Err(e)) => {
                return respond(stream, e.status, "application/json",
                               &error_json(e.message))
            }
            Err(_) => return Ok(()), // dead connection, nothing to send
        };
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => {
                respond(stream, 200, "text/plain", "ok")
            }
            ("GET", "/readyz") => {
                if self.workers.iter().any(|w| w.healthy()) {
                    respond(stream, 200, "text/plain", "ready")
                } else {
                    respond(stream, 503, "text/plain",
                            "no routable workers")
                }
            }
            ("GET", "/metrics") => {
                respond(stream, 200, "text/plain", &self.metrics.export())
            }
            ("POST", "/generate") => self.generate(stream, &req.body),
            _ => respond(stream, 404, "text/plain", "not found"),
        }
    }

    fn generate(&self, stream: &mut TcpStream, body: &str) -> Result<()> {
        let j = match json::parse(body) {
            Ok(j) => j,
            Err(e) => {
                return respond(stream, 400, "application/json",
                               &error_json(&format!("bad json: {e}")))
            }
        };
        let Some(prompt) = j.get("prompt").and_then(|p| p.as_str()) else {
            return respond(stream, 400, "application/json",
                           &error_json("missing prompt"));
        };
        // per-tenant admission first: over-quota traffic never consumes
        // backplane capacity
        let tenant = j
            .get("tenant")
            .and_then(|t| t.as_str())
            .unwrap_or("default");
        let admitted = crate::util::sync::lock_recover(&self.quotas)
            .admit(tenant, Instant::now());
        if !admitted {
            self.metrics.record_cluster_quota_reject();
            self.metrics.record_cluster_shed(429);
            return respond(stream, 429, "application/json",
                           &error_json("tenant over quota"));
        }
        let key = self.key_for(&self.tokenizer.encode(prompt));
        // one retry on a *different* worker after a backplane failure
        // that forwarded nothing — safe (the worker saw at most a
        // partial request) and it absorbs the kill-restart window
        let mut excluded: Option<usize> = None;
        for attempt in 0..2 {
            // on retry the failed worker was marked unhealthy below, so
            // place() already routes around it; the guard arm covers
            // the window where another thread revived it
            let placed = self.place(key);
            let (w, route) = match placed {
                Ok(p) if Some(p.0) == excluded => {
                    // ring still points at the worker that just failed
                    // (health checker hasn't caught up): force fallback
                    match policy::least_loaded(
                        self.workers.iter().enumerate().map(|(i, wk)| {
                            Candidate {
                                idx: i,
                                alive: wk.healthy()
                                    && Some(i) != excluded,
                                has_room: wk.inflight()
                                    < self.cfg.max_inflight,
                                load: wk.inflight() as f64,
                            }
                        }),
                    ) {
                        Ok(i) => (i, ClusterRoute::Fallback),
                        Err(PickError::Saturated) => {
                            self.metrics.record_cluster_shed(429);
                            return respond(
                                stream, 429, "application/json",
                                &error_json("all workers saturated"),
                            );
                        }
                        Err(PickError::NoneAlive) => {
                            self.metrics.record_cluster_shed(503);
                            return respond(
                                stream, 503, "application/json",
                                &error_json("no workers available"),
                            );
                        }
                    }
                }
                Ok(p) => p,
                Err(status) => {
                    self.metrics.record_cluster_shed(status);
                    let msg = if status == 429 {
                        "all workers saturated"
                    } else {
                        "no workers available"
                    };
                    return respond(stream, status, "application/json",
                                   &error_json(msg));
                }
            };
            match self.proxy(w, stream, body) {
                ProxyOutcome::Done => {
                    self.metrics.record_cluster_dispatch(route);
                    return Ok(());
                }
                ProxyOutcome::Retriable => {
                    self.metrics.record_cluster_backplane_error();
                    // a connect/write failure is a strong death signal;
                    // don't wait fail_threshold probes to route around
                    self.workers[w]
                        .healthy
                        .store(false, Ordering::Release);
                    self.metrics.set_cluster_worker(
                        w,
                        false,
                        self.workers[w].inflight(),
                    );
                    excluded = Some(w);
                    if attempt == 0 {
                        self.metrics.record_cluster_retry();
                        continue;
                    }
                }
            }
        }
        respond(stream, 502, "application/json",
                &error_json("backplane failure"))
    }

    /// Forward `body` to worker `w` and pipe the response back. Never
    /// blocks forever: connects under `connect_timeout`, reads under
    /// `proxy_read_timeout` per chunk.
    fn proxy(&self, w: usize, client: &mut TcpStream, body: &str)
             -> ProxyOutcome {
        let worker = &self.workers[w];
        let _guard = InflightGuard::enter(worker);
        let Ok(sock) = worker.addr.parse::<SocketAddr>() else {
            return ProxyOutcome::Retriable;
        };
        let Ok(mut up) =
            TcpStream::connect_timeout(&sock, self.cfg.connect_timeout)
        else {
            return ProxyOutcome::Retriable;
        };
        let _ = up.set_nodelay(true);
        let _ = up.set_read_timeout(Some(self.cfg.proxy_read_timeout));
        let _ = up.set_write_timeout(Some(self.cfg.connect_timeout));
        if write!(
            up,
            "POST /generate HTTP/1.1\r\nHost: {}\r\n\
             Content-Type: application/json\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n{body}",
            worker.addr,
            body.len()
        )
        .is_err()
        {
            return ProxyOutcome::Retriable;
        }
        let _ = client.set_nodelay(true);
        let mut piped = false;
        let mut buf = [0u8; 16 * 1024];
        loop {
            match up.read(&mut buf) {
                Ok(0) => {
                    // EOF before any response byte = the worker died
                    // after accepting: retriable (it processed nothing
                    // it could have answered)
                    return if piped {
                        ProxyOutcome::Done
                    } else {
                        ProxyOutcome::Retriable
                    };
                }
                Ok(n) => {
                    if client.write_all(&buf[..n]).is_err() {
                        // client went away; drop both sides (the worker
                        // notices its own peer_gone probe)
                        return ProxyOutcome::Done;
                    }
                    piped = true;
                }
                Err(_) => {
                    return if piped {
                        // mid-response failure: the client got a
                        // truncated reply; closing tells it so
                        ProxyOutcome::Done
                    } else {
                        ProxyOutcome::Retriable
                    };
                }
            }
        }
    }
}

/// How a proxied request ended.
enum ProxyOutcome {
    /// Response bytes were delivered (fully, or until the client left).
    Done,
    /// Nothing was forwarded to the client — safe to retry elsewhere.
    Retriable,
}

/// Mix a counter into a placement tick (SplitMix64 finalizer) — the
/// random-dispatch baseline's "coin".
fn mix_tick(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Poll `addr`'s `/readyz` until it answers 200 or `deadline` passes.
pub fn wait_ready(addr: &str, deadline: Duration) -> Result<()> {
    let t0 = Instant::now();
    loop {
        if let Ok((200, _)) =
            http_get(addr, "/readyz", Duration::from_millis(250))
        {
            return Ok(());
        }
        if t0.elapsed() > deadline {
            return Err(anyhow!(
                "worker {addr} not ready after {deadline:?}"
            ));
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_mode_parses() {
        assert_eq!(DispatchMode::parse("affinity"),
                   Some(DispatchMode::Affinity));
        assert_eq!(DispatchMode::parse("random"),
                   Some(DispatchMode::Random));
        assert_eq!(DispatchMode::parse("nope"), None);
    }

    #[test]
    fn parse_response_splits_status_and_body() {
        let (status, body) = parse_response(
            "HTTP/1.1 429 Too Many Requests\r\nContent-Length: 2\r\n\r\nhi",
        )
        .unwrap();
        assert_eq!(status, 429);
        assert_eq!(body, "hi");
        assert!(parse_response("garbage").is_err());
    }

    #[test]
    fn placement_is_affine_until_saturated() {
        let metrics = Arc::new(Metrics::new());
        let front = ClusterFront::new(
            vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()],
            ClusterConfig { max_inflight: 1, ..Default::default() },
            metrics,
        );
        let key = 0x1234_5678_9abc_def0;
        let (w, route) = front.place(key).unwrap();
        assert_eq!(route, ClusterRoute::Affine);
        // saturate the affine worker → fallback to the other
        let _g = InflightGuard::enter(&front.workers[w]);
        let (w2, route2) = front.place(key).unwrap();
        assert_eq!(route2, ClusterRoute::Fallback);
        assert_ne!(w2, w);
        // saturate both → 429
        let _g2 = InflightGuard::enter(&front.workers[w2]);
        assert_eq!(front.place(key), Err(429));
        // kill both → 503
        drop((_g, _g2));
        for wk in front.workers() {
            wk.healthy.store(false, Ordering::Release);
        }
        assert_eq!(front.place(key), Err(503));
    }

    #[test]
    fn placement_same_key_same_worker() {
        let metrics = Arc::new(Metrics::new());
        let front = ClusterFront::new(
            (0..4).map(|i| format!("127.0.0.1:{}", i + 1)).collect(),
            ClusterConfig::default(),
            metrics,
        );
        for k in 0..200u64 {
            let key = mix_tick(k + 1);
            let (w1, _) = front.place(key).unwrap();
            let (w2, _) = front.place(key).unwrap();
            assert_eq!(w1, w2, "same key must stay affine");
        }
    }

    #[test]
    fn random_mode_spreads_and_sheds() {
        let metrics = Arc::new(Metrics::new());
        let front = ClusterFront::new(
            vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()],
            ClusterConfig {
                dispatch: DispatchMode::Random,
                max_inflight: 1,
                ..Default::default()
            },
            metrics,
        );
        let key = 42;
        let mut seen = [false; 2];
        for _ in 0..64 {
            let (w, route) = front.place(key).unwrap();
            assert_eq!(route, ClusterRoute::Random);
            seen[w] = true;
        }
        assert!(seen[0] && seen[1], "random must use both workers");
        let _g0 = InflightGuard::enter(&front.workers[0]);
        let _g1 = InflightGuard::enter(&front.workers[1]);
        assert_eq!(front.place(key), Err(429));
    }

    #[test]
    fn inflight_guard_is_exception_safe() {
        let w = Worker::new("127.0.0.1:1".into());
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let _g = InflightGuard::enter(&w);
                assert_eq!(w.inflight(), 1);
                panic!("boom");
            }),
        );
        assert!(r.is_err());
        assert_eq!(w.inflight(), 0, "guard must release on panic");
    }
}
